"""EF-sign kernel micro-bench: jnp reference path timing on CPU (wall-clock)
plus the derived TPU-side HBM-traffic model for the fused Pallas kernel.

On this CPU container the Pallas kernel runs in interpret mode (Python), so
wall-clock compares the jit'd REFERENCE path against the unfused 4-pass jnp
pipeline; the 'derived' column reports modeled HBM bytes per element
(fused = 1×read g + 1×read e + 1×write e' + 1/32 write words ≈ 12.1 B/elem
vs unfused ≈ 4 passes ≈ 40+ B/elem → the ~3.3× bound on the compression
stage; see EXPERIMENTS.md §Perf)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.compressors import ScaledSignCompressor
from repro.kernels import ops


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_rows():
    rows = []
    comp = ScaledSignCompressor()

    @jax.jit
    def unfused(g, e, gamma):
        p = gamma * g + e
        payload = comp.compress(p)
        delta = comp.decompress(payload, g.shape[0])
        return payload.words, payload.scale, p - delta

    fused = lambda g, e, gamma: ops.ef_sign_step(g, e, gamma, force="ref")

    for n in (1 << 16, 1 << 20, 1 << 23):
        g = jax.random.normal(jax.random.PRNGKey(0), (n,))
        e = jax.random.normal(jax.random.PRNGKey(1), (n,))
        gamma = jnp.float32(0.01)
        t_un = _time(unfused, g, e, gamma)
        t_fu = _time(fused, g, e, gamma)
        rows.append((f"ef_sign_unfused_n{n}", round(t_un, 1), 0))
        rows.append((f"ef_sign_fusedref_n{n}", round(t_fu, 1), round(t_un / t_fu, 2)))
    # modeled HBM bytes/element on TPU: fused pallas vs composed XLA
    rows.append(("ef_sign_model_bytes_fused", 0.0, 12.1))
    rows.append(("ef_sign_model_bytes_unfused", 0.0, 40.3))
    return rows
