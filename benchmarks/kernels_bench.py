"""EF-sign kernel micro-bench — thin wrapper over the registered benches in
``repro.bench.suites.kernels`` (run ``python -m repro.bench run --suite
kernels`` for the JSON artifact; this module keeps the benchmarks.run CSV)."""

from __future__ import annotations

from repro.bench.artifact import legacy_rows
from repro.bench.registry import BenchContext
from repro.bench.suites import kernels as K


def run_rows():
    ctx = BenchContext(suite="kernels", fast=False)
    metrics = K.ef_sign_fused_vs_unfused(ctx) + K.ef_sign_hbm_model(ctx)
    return legacy_rows(metrics)
