"""Paper §3 counterexamples (Fig. 1 claims) as a benchmark table.

CE1: linear f with bimodal noise — SIGNSGD ascends, SGD/EF descend.
CE2: non-smooth convex — SIGNSGD trapped on x₁+x₂=2 for ANY step sequence.
CE3: smooth least squares, batch-1 stochastic — SIGNSGD trapped a.s.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaledSignCompressor, ef_step, init_ef_state


def _sgn(x):
    # the paper's sign operator: sign(0) = +1 (matches our compressors)
    return jnp.where(x >= 0, 1.0, -1.0)


def ce1(steps=4000, gamma=0.05, seed=0):
    key = jax.random.PRNGKey(seed)
    res = {}
    for name in ("sgd", "signsgd", "ef_signsgd"):
        k = key
        x = jnp.float32(0.0)
        state = init_ef_state({"x": jnp.zeros(())})
        for _ in range(steps):
            k, sub = jax.random.split(k)
            g = jnp.where(jax.random.uniform(sub) < 0.25, 4.0, -1.0)
            if name == "sgd":
                x = x - gamma * g
            elif name == "signsgd":
                x = x - gamma * _sgn(g)
            else:
                out, state = ef_step(ScaledSignCompressor(), {"x": -gamma * g}, state)
                x = x + out["x"]
            x = jnp.clip(x, -1.0, 1.0)
        res[name] = float(x) / 4  # f(x) = x/4, optimum −0.25
    return res


def _ce2_grad(x, eps=0.5):
    # subgradient with the paper's sign(0)=+1 choice — at x₁=x₂ the
    # adversarial subgradient keeps sign(g)=±(1,−1) (paper §3, CE2)
    s1 = _sgn(x[0] + x[1])
    s2 = _sgn(x[0] - x[1])
    return s1 * eps * jnp.array([1.0, 1.0]) + s2 * jnp.array([1.0, -1.0])


def ce2(steps=800, eps=0.5):
    f = lambda x: eps * jnp.abs(x[0] + x[1]) + jnp.abs(x[0] - x[1])
    res = {}
    x = jnp.array([1.0, 1.0])
    for t in range(steps):
        x = x - 0.05 / np.sqrt(t + 1) * _sgn(_ce2_grad(x, eps))
    res["signsgd_f"] = float(f(x))
    res["signsgd_line"] = float(x[0] + x[1])  # stays 2.0 — trapped

    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    for t in range(steps):
        out, state = ef_step(ScaledSignCompressor(), {"x": -0.05 * _ce2_grad(x, eps)}, state)
        x = x + out["x"]
    res["ef_signsgd_f"] = float(f(x))
    return res


def ce3(steps=1500, eps=0.5, seed=0):
    a1 = jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    a2 = -jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    f = lambda x: jnp.dot(a1, x) ** 2 + jnp.dot(a2, x) ** 2

    def g(x, key):
        pick = jax.random.uniform(key) < 0.5
        ai = jnp.where(pick, 1.0, 0.0) * a1 + jnp.where(pick, 0.0, 1.0) * a2
        return 4 * jnp.dot(ai, x) * ai

    res = {}
    key = jax.random.PRNGKey(seed)
    x = jnp.array([1.0, 1.0])
    for t in range(steps):
        key, sub = jax.random.split(key)
        x = x - 0.02 / np.sqrt(t + 1) * _sgn(g(x, sub))
    res["signsgd_f"] = float(f(x))

    key = jax.random.PRNGKey(seed)
    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    for t in range(steps):
        key, sub = jax.random.split(key)
        out, state = ef_step(ScaledSignCompressor(), {"x": -0.02 * g(x, sub)}, state)
        x = x + out["x"]
    res["ef_signsgd_f"] = float(f(x))
    return res


def run():
    rows = []
    t0 = time.perf_counter()
    r1 = ce1()
    rows.append(("ce1_sgd_f", (time.perf_counter() - t0) * 1e6, r1["sgd"]))
    rows.append(("ce1_signsgd_f", 0.0, r1["signsgd"]))
    rows.append(("ce1_ef_signsgd_f", 0.0, r1["ef_signsgd"]))
    t0 = time.perf_counter()
    r2 = ce2()
    rows.append(("ce2_signsgd_f", (time.perf_counter() - t0) * 1e6, r2["signsgd_f"]))
    rows.append(("ce2_signsgd_trapline", 0.0, r2["signsgd_line"]))
    rows.append(("ce2_ef_signsgd_f", 0.0, r2["ef_signsgd_f"]))
    t0 = time.perf_counter()
    r3 = ce3()
    rows.append(("ce3_signsgd_f", (time.perf_counter() - t0) * 1e6, r3["signsgd_f"]))
    rows.append(("ce3_ef_signsgd_f", 0.0, r3["ef_signsgd_f"]))
    return rows
