"""Paper §3 counterexamples (Fig. 1 claims) — thin wrapper over the ported
implementations in ``repro.bench.suites.convergence`` (run ``python -m
repro.bench run --suite convergence`` for the gated JSON artifact)."""

from __future__ import annotations

import time

from repro.bench.suites.convergence import ce1, ce2, ce3  # noqa: F401 (re-export)


def run():
    rows = []
    t0 = time.perf_counter()
    r1 = ce1()
    rows.append(("ce1_sgd_f", (time.perf_counter() - t0) * 1e6, r1["sgd"]))
    rows.append(("ce1_signsgd_f", 0.0, r1["signsgd"]))
    rows.append(("ce1_ef_signsgd_f", 0.0, r1["ef_signsgd"]))
    t0 = time.perf_counter()
    r2 = ce2()
    rows.append(("ce2_signsgd_f", (time.perf_counter() - t0) * 1e6, r2["signsgd_f"]))
    rows.append(("ce2_signsgd_trapline", 0.0, r2["signsgd_line"]))
    rows.append(("ce2_ef_signsgd_f", 0.0, r2["ef_signsgd_f"]))
    t0 = time.perf_counter()
    r3 = ce3()
    rows.append(("ce3_signsgd_f", (time.perf_counter() - t0) * 1e6, r3["signsgd_f"]))
    rows.append(("ce3_ef_signsgd_f", 0.0, r3["ef_signsgd_f"]))
    return rows
