"""Paper §6 / Fig. 4 / Tables 1,3,4 protocol at CPU scale.

CIFAR+Resnet18 is replaced by a synthetic teacher task + MLP (no datasets
offline — deviation recorded in DESIGN.md §8.2); the *protocol* is the
paper's: 4 algorithms (SGDM, scaled SIGNSGD, SIGNSGDM, EF-SIGNSGD), batch
sizes {128, 32, 8}, LR tuned at batch 128 and scaled linearly for smaller
batches (Goyal et al.), /10 decimation at 50%/75% of training, weight decay
5e-4 for all. Reported: train/test accuracy and the generalization gap vs
SGDM; qualitative targets: EF ≈ SGDM on test, sign methods degrade as batch
shrinks (Table 1's −36% at batch 8 is the headline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_updates, get_optimizer
from repro.core.optim import step_decay_schedule
from repro.data.synthetic import proxy_classification

DIM, CLASSES, WIDTH = 256, 10, 256


def _init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (DIM, WIDTH)) / np.sqrt(DIM),
        "b1": jnp.zeros((WIDTH,)),
        "w2": jax.random.normal(k2, (WIDTH, WIDTH)) / np.sqrt(WIDTH),
        "b2": jnp.zeros((WIDTH,)),
        "w3": jax.random.normal(k3, (WIDTH, CLASSES)) / np.sqrt(WIDTH),
        "b3": jnp.zeros((CLASSES,)),
    }


def _logits(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def _loss(p, x, y):
    lp = jax.nn.log_softmax(_logits(p, x))
    return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=-1))


def _acc(p, x, y):
    return float(jnp.mean(jnp.argmax(_logits(p, x), -1) == y))


# LR grid per paper A.3 (log-spaced), tuned at batch 128 on held-out loss,
# then linearly scaled for smaller batches (Goyal et al.) — §6.1 recipe.
LR_GRID = (1e-4, 3.2e-4, 1e-3, 3.2e-3, 1e-2, 3.2e-2, 1e-1, 3.2e-1, 1.0)


def tune_lrs(seed: int = 0, epochs: int = 5, bsz: int = 128) -> dict:
    """Paper A.3: constant-LR short runs; pick the best held-out loss."""
    (xtr, ytr), (xte, yte) = proxy_classification(seed)
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    n = len(xtr)
    best = {}
    for name in ("sgdm", "signsgd", "signum", "ef_signsgd"):
        scores = []
        for lr in LR_GRID:
            opt = get_optimizer(name, lr, weight_decay=5e-4)
            params = _init(jax.random.PRNGKey(seed))
            st = opt.init(params)

            @jax.jit
            def step(p, s, x, y):
                g = jax.grad(_loss)(p, x, y)
                u, s = opt.update(g, s, p)
                return apply_updates(p, u), s

            rng = np.random.default_rng(seed)
            for e in range(epochs):
                perm = rng.permutation(n)
                for i in range(n // bsz):
                    idx = perm[i * bsz : (i + 1) * bsz]
                    params, st = step(params, st, xtr_j[idx], ytr_j[idx])
            test_loss = float(_loss(params, xte_j, yte_j))
            scores.append((test_loss if np.isfinite(test_loss) else 1e9, lr))
        best[name] = min(scores)[1]
    return best


def run(batch_sizes=(128, 32, 8), epochs=30, seed=0, base_lrs: dict | None = None):
    base_lrs = base_lrs or tune_lrs(seed)
    (xtr, ytr), (xte, yte) = proxy_classification(seed)
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    n = len(xtr)
    results = {"lrs": base_lrs}
    for bsz in batch_sizes:
        steps_per_epoch = n // bsz
        total = epochs * steps_per_epoch
        for name, base_lr in base_lrs.items():
            lr = base_lr * bsz / 128.0
            sched = step_decay_schedule(lr, total)
            opt = get_optimizer(name, sched, weight_decay=5e-4)
            params = _init(jax.random.PRNGKey(seed))
            st = opt.init(params)

            @jax.jit
            def step(p, s, x, y):
                g = jax.grad(_loss)(p, x, y)
                u, s = opt.update(g, s, p)
                return apply_updates(p, u), s

            rng = np.random.default_rng(seed)
            for e in range(epochs):
                perm = rng.permutation(n)
                for i in range(steps_per_epoch):
                    idx = perm[i * bsz : (i + 1) * bsz]
                    params, st = step(params, st, xtr_j[idx], ytr_j[idx])
            results[(bsz, name)] = {
                "train_acc": _acc(params, xtr_j, ytr_j),
                "test_acc": _acc(params, xte_j, yte_j),
            }
    # generalization gaps vs SGDM (paper Table 1 format)
    gaps = {}
    for bsz in batch_sizes:
        ref = results[(bsz, "sgdm")]["test_acc"]
        for name in base_lrs:
            gaps[(bsz, name)] = results[(bsz, name)]["test_acc"] - ref
    return results, gaps


def run_rows(fast: bool = True):
    results, gaps = run(epochs=10 if fast else 30)
    rows = []
    for name, lr in results.pop("lrs").items():
        rows.append((f"proxy_lr_{name}", 0.0, lr))
    for (bsz, name), r in results.items():
        rows.append((f"proxy_b{bsz}_{name}_train_acc", 0.0, round(r["train_acc"], 4)))
        rows.append((f"proxy_b{bsz}_{name}_test_acc", 0.0, round(r["test_acc"], 4)))
        rows.append((f"proxy_b{bsz}_{name}_gap_vs_sgdm", 0.0, round(gaps[(bsz, name)], 4)))
    return rows
