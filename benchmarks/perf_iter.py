"""§Perf hill-climb runner: re-lowers a chosen (arch × shape) with a named
variant and records the roofline delta vs baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter --pair granite_train --variant a2a

Variants are hypothesis-driven changes (see EXPERIMENTS.md §Perf for the
napkin math); each run writes benchmarks/results/dryrun/<combo>__<tag>.json
so baseline and variants sit side by side.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

PAIRS = {
    # most representative of the paper's technique: EF-sign aggregation over
    # the 16-way data axis (collective-bound)
    "granite_train": ("granite_moe_1b_a400m", "train_4k"),
    # worst memory fit + biggest model (fsdp + EF optimizer)
    "jamba_train": ("jamba_1_5_large_398b", "train_4k"),
    # worst useful-FLOPs fraction: 32k prefill with masked-full attention on
    # a sliding-window arch
    "llava_prefill": ("llava_next_mistral_7b", "prefill_32k"),
}

VARIANTS = {
    # gradient-exchange changes (granite_train)
    "baseline": {},
    "a2a": {"strategy": "ef_alltoall"},
    "dense": {"strategy": "dense"},
    # attention changes (llava_prefill)
    "winslice_c1k": {"window_slicing": True, "attn_chunk": 1024},
    "chunk1k": {"attn_chunk": 1024},
    # jamba memory/collective changes
    "seqchunk2k": {"attn_chunk": 2048},
    "nosp": {"cfg_overrides": {"residual_seq_shard": False}},
    "ssmremat": {"cfg_overrides": {"ssm_chunk_remat": True}},
    "ssmremat_nosp": {"cfg_overrides": {"ssm_chunk_remat": True, "residual_seq_shard": False}},
    "winslice": {"cfg_overrides": {"attn_window_slicing": True}},
    "winslice_ssmremat": {"cfg_overrides": {"attn_window_slicing": True, "ssm_chunk_remat": True}},
}


def run(pair: str, variant: str, out_dir: str):
    from repro.launch.dryrun import RESULTS_DIR, lower_combo

    arch, shape = PAIRS[pair]
    kw = dict(VARIANTS[variant])
    kw.pop("window_slicing", None)
    overrides = kw.pop("cfg_overrides", None)
    if overrides:
        # flip config fields through the registry so lower_combo sees them
        import repro.configs.base as base
        import repro.launch.dryrun as dr

        orig = base.get_config

        def patched(a):
            return dataclasses.replace(orig(a), **overrides)

        base.get_config = patched
        dr.get_config = patched

    rec = lower_combo(arch, shape, multi_pod=False, **kw)
    # canonical record path shared with the repro.bench roofline suite reader
    from repro.bench.suites.roofline import dryrun_record_path

    path = dryrun_record_path(out_dir, arch, shape, "single", f"{pair}-{variant}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(
        f"{pair}/{variant}: compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
        f"collective={r['collective_s']:.3f}s dominant={r['dominant']} "
        f"temp={rec['memory'].get('temp_size_in_bytes',0)/2**30:.1f}GiB "
        f"useful={rec['useful_flops_ratio']:.3f}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    from repro.launch.dryrun import RESULTS_DIR

    out = args.out or RESULTS_DIR
    os.makedirs(out, exist_ok=True)
    run(args.pair, args.variant, out)


if __name__ == "__main__":
    main()
