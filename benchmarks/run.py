"""Legacy benchmark harness — one module per paper table/figure.

Prefer ``python -m repro.bench run --suite <name>`` (the registry-driven
subsystem with JSON artifacts and baseline gating); this CSV harness remains
for the paper-table modules not yet ported (nn_proxy, density_fig2) and for
quick eyeballing.

Prints ``name,us_per_call,derived`` CSV. Modules:
  counterexamples   — paper §3 / Fig. 1 (CE1–CE3)
  generalization    — paper §5.2 / Fig. 3 (Wilson least-squares, span distance)
  sparse_noise      — paper A.1 / Fig. 5
  density_fig2      — paper Fig. 2 (density of g vs g+e during training)
  nn_proxy          — paper §6 / Fig. 4 + Tables 1/3/4 protocol (synthetic proxy)
  compression       — paper §6.1 wire-bits accounting (~32× claim)
  kernels_bench     — fused EF-sign kernel stage
  roofline          — §Roofline summary from dry-run records (if present)

Usage: PYTHONPATH=src python -m benchmarks.run [--only name] [--full]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true", help="full-length nn_proxy run")
    args = ap.parse_args()

    from benchmarks import (
        compression,
        counterexamples,
        density_fig2,
        generalization,
        kernels_bench,
        nn_proxy,
        roofline,
        sparse_noise,
    )

    suites = {
        "counterexamples": counterexamples.run,
        "generalization": generalization.run_rows,
        "sparse_noise": sparse_noise.run_rows,
        "density_fig2": density_fig2.run_rows,
        "nn_proxy": lambda: nn_proxy.run_rows(fast=not args.full),
        "compression": compression.run_rows,
        "kernels_bench": kernels_bench.run_rows,
        "roofline": roofline.run_rows,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    all_rows = []
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # a missing dry-run dir shouldn't kill the run
            print(f"{name}_ERROR,0,{type(e).__name__}", flush=True)
            failures += 1
            continue
        wall = (time.perf_counter() - t0) * 1e6
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]}", flush=True)
            all_rows.append(r)
        print(f"{name}_total,{wall:.0f},{len(rows)}", flush=True)

    out_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "bench_rows.json"), "w") as f:
        json.dump([list(r) for r in all_rows], f, indent=1)
    # propagate failure like the repro.bench CLI does (exit 2 = bench error),
    # so local regression runs fail loudly instead of printing _ERROR rows
    # and exiting 0
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
