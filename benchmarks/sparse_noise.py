"""Paper Appendix A.1 / Fig. 5 (sparse-noise toy) — thin wrapper over the
ported implementation in ``repro.bench.suites.convergence.sparse_noise_run``."""

from __future__ import annotations

from repro.bench.suites.convergence import sparse_noise_run as run


def run_rows():
    res = run()
    return [(f"sparsenoise_{k}_f", 0.0, mean) for k, (mean, std) in res.items()]
