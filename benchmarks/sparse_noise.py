"""Paper Appendix A.1 / Fig. 5: the sparse-noise toy.

f(x)=½‖x‖² in R¹⁰⁰ with N(0,100²) noise on coordinate 0 only. Claim: SIGNSGD
and scaled-SIGNSGD are FAST here (sign caps the noisy coordinate) while SGD
and EF-SIGNSGD converge at the same SLOWER rate — the result that contradicts
the 'bad coordinate' explanation when compared with real-data behavior.
Paper's tuned LRs: 1e-3 for SGD/EF, 1e-2 for the sign methods.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaledSignCompressor, ef_step, init_ef_state
from repro.data.synthetic import sparse_noise_grad


def run(steps: int = 400, reps: int = 20, seed: int = 0):
    d = 100
    lrs = {"sgd": 1e-3, "ef_signsgd": 1e-3, "signsgd": 1e-2, "scaled_signsgd": 1e-2}
    finals: dict[str, list[float]] = {k: [] for k in lrs}
    for rep in range(reps):
        key = jax.random.PRNGKey(seed * 1000 + rep)
        for name, lr in lrs.items():
            k = key
            x = jnp.ones((d,)) * 5.0
            state = init_ef_state({"x": x})
            for t in range(steps):
                k, sub = jax.random.split(k)
                g = sparse_noise_grad(sub, x)
                if name == "sgd":
                    x = x - lr * g
                elif name == "signsgd":
                    x = x - lr * jnp.sign(g)
                elif name == "scaled_signsgd":
                    x = x - lr * jnp.mean(jnp.abs(g)) * jnp.sign(g)
                else:
                    out, state = ef_step(ScaledSignCompressor(), {"x": -lr * g}, state)
                    x = x + out["x"]
            finals[name].append(float(0.5 * jnp.sum(x * x)))
    return {k: (float(np.mean(v)), float(np.std(v))) for k, v in finals.items()}


def run_rows():
    res = run()
    return [(f"sparsenoise_{k}_f", 0.0, mean) for k, (mean, std) in res.items()]
