"""Paper §5.2 / Fig. 3 (Wilson least squares) — thin wrapper over the ported
implementation in ``repro.bench.suites.convergence.wilson_run``."""

from __future__ import annotations

from repro.bench.suites.convergence import wilson_run as run


def run_rows():
    res = run()
    rows = []
    for name, r in res.items():
        rows.append((f"wilson_{name}_train", 0.0, r["train_loss"]))
        rows.append((f"wilson_{name}_test", 0.0, r["test_loss"]))
        rows.append((f"wilson_{name}_spandist", 0.0, r["span_dist"]))
    return rows
