"""Paper §5.2 / Fig. 3: over-parameterized least squares, exact A.6 data gen.

Four full-batch-gradient algorithms; we track train loss, test loss, and the
distance of the iterate from the span of observed gradients
‖x_t − Π_{G_t} x_t‖ (Theorem IV / Lemma 9: EF → min-norm/max-margin solution).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaledSignCompressor, ef_step, init_ef_state
from repro.data.synthetic import wilson_least_squares


def run(steps: int = 4000, seed: int = 0):
    data = wilson_least_squares(seed)
    a = jnp.asarray(data.a_train, jnp.float32)
    y = jnp.asarray(data.y_train, jnp.float32)
    at = jnp.asarray(data.a_test, jnp.float32)
    yt = jnp.asarray(data.y_test, jnp.float32)
    n, d = a.shape

    def train_loss(x):
        return jnp.mean((a @ x - y) ** 2)

    def test_loss(x):
        return float(jnp.mean((at @ x - yt) ** 2))

    grad = jax.jit(jax.grad(train_loss))

    def span_distance(x, gmat):
        # distance to span of gradients ≡ component outside row-space of A
        coef, *_ = np.linalg.lstsq(gmat, np.asarray(x), rcond=None)
        return float(np.linalg.norm(np.asarray(x) - gmat @ coef))

    gmat = np.asarray(data.a_train).T  # gradients live in span(rows of A)

    results = {}
    lrs = {"sgd": 0.05, "signsgd": 0.002, "signum": 0.002, "ef_signsgd": 0.05}
    for name in ("sgd", "signsgd", "signum", "ef_signsgd"):
        lr = lrs[name]
        x = jnp.zeros((d,))
        m = jnp.zeros((d,))
        state = init_ef_state({"x": x})
        for t in range(steps):
            g = grad(x)
            if name == "sgd":
                x = x - lr * g
            elif name == "signsgd":
                x = x - lr * jnp.sign(g)
            elif name == "signum":
                m = g + 0.9 * m
                x = x - lr * jnp.sign(m)
            else:
                out, state = ef_step(ScaledSignCompressor(), {"x": -lr * g}, state)
                x = x + out["x"]
        results[name] = {
            "train_loss": float(train_loss(x)),
            "test_loss": test_loss(x),
            "span_dist": span_distance(x, gmat),
        }
    return results


def run_rows():
    res = run()
    rows = []
    for name, r in res.items():
        rows.append((f"wilson_{name}_train", 0.0, r["train_loss"]))
        rows.append((f"wilson_{name}_test", 0.0, r["test_loss"]))
        rows.append((f"wilson_{name}_spandist", 0.0, r["span_dist"]))
    return rows
