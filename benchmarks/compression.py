"""Communication accounting (paper §6.1's Σ(dᵢ+32)-bit claim, ~32×).

Per assigned architecture: exact wire bits per training step for dense fp32
vs scaled-sign vs top-k vs qsgd (layer-wise compression over the real
parameter tree of the reduced config, plus analytic numbers for the full
config sizes)."""

from __future__ import annotations

import jax

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core.compressors import get_compressor, tree_wire_bits
from repro.models import transformer as T


def run_rows():
    rows = []
    comps = {
        "dense": get_compressor("identity"),
        "sign": get_compressor("scaled_sign"),
        "top_k": get_compressor("top_k", k=64),
        "qsgd4bit": get_compressor("qsgd", s=7),
    }
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        bits = {name: tree_wire_bits(c, params) for name, c in comps.items()}
        for name, b in bits.items():
            rows.append((f"wire_{arch}_{name}_bits", 0.0, b))
        rows.append(
            (f"wire_{arch}_sign_reduction", 0.0, round(bits["dense"] / bits["sign"], 2))
        )
        # analytic full-size numbers: Σᵢ(dᵢ+32) with dᵢ the real leaf sizes
        full = get_config(arch)
        total, _ = full.param_counts()
        rows.append((f"wire_{arch}_full_dense_GB", 0.0, round(total * 4 / 2**30, 2)))
        rows.append((f"wire_{arch}_full_sign_GB", 0.0, round(total / 8 / 2**30, 3)))
    return rows
