"""Communication accounting (paper §6.1's Σ(dᵢ+32)-bit claim, ~32×) — thin
wrapper over ``repro.bench.suites.aggregation.wire_bits_accounting`` (run
``python -m repro.bench run --suite aggregation`` for the JSON artifact)."""

from __future__ import annotations

from repro.bench.artifact import legacy_rows
from repro.bench.registry import BenchContext
from repro.bench.suites.aggregation import wire_bits_accounting


def run_rows():
    ctx = BenchContext(suite="aggregation", fast=False)
    return legacy_rows(wire_bits_accounting(ctx))
