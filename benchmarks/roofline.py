"""§Roofline table generator — thin wrapper over
``repro.bench.suites.roofline`` (run ``python -m repro.bench run --suite
roofline`` for the gated JSON artifact; this module keeps the markdown table
and the benchmarks.run CSV rows)."""

from __future__ import annotations

from repro.bench.artifact import legacy_rows
from repro.bench.registry import BenchContext, SkipBench
from repro.bench.suites import roofline as R

RESULTS = R.RESULTS_DIR
HBM_PER_CHIP = R.HBM_PER_CHIP

load = R.load_records
table = R.markdown_table


def run_rows():
    try:
        return legacy_rows(R.roofline_records(BenchContext(suite="roofline")))
    except SkipBench:
        return []


if __name__ == "__main__":
    print(table())
