"""§Roofline table generator: reads the dry-run JSON records and renders the
three-term roofline per (arch × shape), flags the dominant term, computes
MODEL_FLOPS/HLO_FLOPS, and emits the markdown for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
HBM_PER_CHIP = 16 * 2**30  # v5e


def load(mesh: str = "single", tag: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}*.json"))):
        stem = os.path.basename(path)[: -len(".json")]
        parts = stem.split("__")
        if tag is None and len(parts) > 3:
            continue
        if tag is not None and (len(parts) < 4 or parts[3] != tag):
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(mesh="single", tag=None) -> str:
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | policy/strategy | compute_s | memory_s | collective_s "
        "| dominant | model/HLO flops | state+temp GiB/chip | fits? |",
        "|---|---|---|---|---|---|---|---|---|---|"[:-4] or "",
    ]
    lines[1] = "|---|---|---|---|---|---|---|---|---|"
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        m = r["memory"]
        state = m.get("argument_size_in_bytes", 0)
        temp = m.get("temp_size_in_bytes", 0)
        gib = (state + temp) / 2**30
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']}/{r['strategy']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| **{rf['dominant'].replace('_s','')}** "
            f"| {r['useful_flops_ratio']:.3f} | {gib:.1f} "
            f"| {'Y' if (state + temp) <= HBM_PER_CHIP else 'over'} |"
        )
    return "\n".join(lines)


def run_rows():
    rows = []
    for r in load("single"):
        name = f"roofline_{r['arch']}_{r['shape']}"
        dom = r["roofline"]["dominant"]
        rows.append((name + "_dominant_" + dom, 0.0,
                     round(r["roofline"][dom], 4)))
        rows.append((name + "_useful_flops", 0.0, round(r["useful_flops_ratio"], 3)))
    return rows


if __name__ == "__main__":
    print(table())
