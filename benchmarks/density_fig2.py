"""Paper Fig. 2: the density φ(·) of stochastic gradients vs error-corrected
gradients during real training.

The paper plots φ(g_t) and φ(g_t + e_t) for VGG19/CIFAR10 (batch 128) and
notes min φ(g+e) > 0.13 — the corrected direction stays dense, which is what
makes the scaled-sign compressor's effective δ benign (Lemma 8). We reproduce
the measurement on a ~10M-param transformer trained with EF-SIGNSGD on
synthetic tokens, logging per-leaf densities along the run.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ScaledSignCompressor, apply_updates, corrected_density, ef_step, init_ef_state
from repro.core.compressors import density
from repro.data.synthetic import token_batches
from repro.models import transformer as T


def run(steps: int = 60, lr: float = 0.05, seed: int = 0):
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        name="llama-10m", num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=1024, vocab_size=2048,
        param_dtype="float32", compute_dtype="float32", attn_chunk=64,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    state = init_ef_state(params)
    comp = ScaledSignCompressor()
    batches = token_batches(seed, 8, 64, cfg.vocab_size)

    grad_fn = jax.jit(jax.grad(lambda p, b: T.loss_fn(p, cfg, b)[0]))

    dens_g, dens_corrected = [], []
    for i in range(steps):
        batch = next(batches)
        g = grad_fn(params, batch)
        u = jax.tree.map(lambda x: -lr * x, g)
        # measure BEFORE the step, matching the paper's φ(g) vs φ(g+e)
        dens_g.append([float(density(x)) for x in jax.tree.leaves(g)])
        dens_corrected.append(
            [float(d) for d in jax.tree.leaves(corrected_density(u, state))]
        )
        out, state = ef_step(comp, u, state)
        params = apply_updates(params, out)

    dg = np.array(dens_g[5:])  # skip warmup, as the paper's histogram does
    dc = np.array(dens_corrected[5:])
    return {
        "grad_density_mean": float(dg.mean()),
        "grad_density_min": float(dg.min()),
        "corrected_density_mean": float(dc.mean()),
        "corrected_density_min": float(dc.min()),
    }


def run_rows():
    r = run()
    return [(f"fig2_{k}", 0.0, round(v, 4)) for k, v in r.items()]
