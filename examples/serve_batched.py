"""Serving example: prefill a batch of prompts and decode with the engine.

Exercises the same prefill/decode steps the dry-run lowers for the
inference shapes (decode_32k / long_500k), at reduced scale on CPU, across
three architecture families (dense, SSM, hybrid-MoE).

    PYTHONPATH=src python examples/serve_batched.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serve.engine import DecodeEngine, ServeConfig


def main():
    mesh = make_host_mesh(data=1, model=1)
    for arch in ("llama3.2-1b", "falcon-mamba-7b", "jamba-1.5-large-398b"):
        cfg = dataclasses.replace(
            reduced(get_config(arch)), capacity_factor=4.0
        )
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        engine = DecodeEngine(cfg, mesh, params, ServeConfig(max_len=96, temperature=0.0))
        prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)}
        out = engine.generate(prompts, new_tokens=12)
        print(f"{arch:24s} generated {out.shape} tokens; first row: {list(map(int, out[0]))}")
        assert out.shape == (4, 12)
        assert int(jnp.max(out)) < cfg.vocab_size


if __name__ == "__main__":
    main()
