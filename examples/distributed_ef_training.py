"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with multi-worker error-feedback compressed gradient aggregation.

This is the paper's algorithm as a *distributed systems feature*: per-worker
EF-sign compression, all-gather exchange (or the beyond-paper all-to-all
double compression with ``--strategy ef_alltoall``), identical aggregated
updates everywhere, ~32× less gradient traffic than dense fp32.

On the CPU container this runs on a host mesh with fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_ef_training.py --steps 200

(The env var is set inside the script if unset, before jax imports.)
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--strategy", default="ef_allgather",
                    choices=["dense", "ef_allgather", "ef_alltoall", "majority_vote"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.train.loop import TrainJob, run_training

    # ~100M params: llama3.2-1b family scaled to 8 layers / d512
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        name="llama-100m", num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000,
        param_dtype="float32", compute_dtype="float32", attn_chunk=128,
    )
    total, _ = cfg.param_counts()
    print(f"model: {cfg.name}  params={total/1e6:.1f}M  strategy={args.strategy}")

    mesh = make_host_mesh(data=4, model=2)
    job = TrainJob(
        cfg=cfg, mesh=mesh, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=0.01, optimizer="sgd", strategy=args.strategy, policy="tp",
        log_every=20,
    )
    _, hist = run_training(job, log_fn=lambda r: print(json.dumps(r), flush=True))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f}; "
          f"wire bytes/step/device = {hist[-1]['wire_bytes']:.3g}; "
          f"corrected-gradient density φ = {hist[-1]['density']:.3f}")
    # short smoke runs (< ~100 steps) don't move the loss at this model/batch
    # scale on ANY strategy (dense included) — only assert convergence on the
    # documented few-hundred-step horizon
    if args.steps >= 100:
        assert last < first


if __name__ == "__main__":
    main()
