"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with multi-worker error-feedback compressed gradient aggregation.

This is the paper's algorithm as a *distributed systems feature*: per-worker
EF-sign compression, all-gather exchange (or the beyond-paper all-to-all
double compression with ``--strategy ef_alltoall``), identical aggregated
updates everywhere, ~32× less gradient traffic than dense fp32.

On the CPU container this runs on a host mesh with fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_ef_training.py --steps 200

(The env var is set inside the script if unset, before jax imports.)
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--strategy", default="ef_allgather",
                    choices=["dense", "ef_allgather", "ef_ring", "ef_alltoall",
                             "majority_vote", "ef_coord_median",
                             "ef_trimmed_mean", "ef_norm_filter"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--overlap", action="store_true",
                    help="pipeline bucket compression + collectives with the "
                    "backward (repro.overlap) and report comm exposure per step")
    ap.add_argument("--overlap-groups", type=int, default=None,
                    help="overlap pipeline depth (implies --overlap)")
    ap.add_argument("--byz-attack", default=None,
                    help="corrupt EF-worker lanes (sign_flip | scaled_noise | "
                    "zero_out | const_drift; repro.comm.adversary)")
    ap.add_argument("--byz-fraction", type=float, default=None,
                    help="fraction of workers the injector corrupts")
    ap.add_argument("--byz-f", type=int, default=None,
                    help="declared tolerance for the robust strategies (2f < W)")
    ap.add_argument("--backend", default="auto",
                    help="collective backend for the payload-mean exchange "
                    "(auto | xla | ring | pallas_dma; pallas_dma falls back "
                    "to ring off-TPU with a logged reason)")
    args = ap.parse_args()

    from repro.comm import CommSpec
    from repro.configs import get_config
    from repro.configs.base import ByzConfig, OverlapConfig
    from repro.launch.mesh import make_host_mesh
    from repro.train.loop import TrainJob, run_training

    # ~100M params: llama3.2-1b family scaled to 8 layers / d512
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        name="llama-100m", num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000,
        param_dtype="float32", compute_dtype="float32", attn_chunk=128,
    )
    total, _ = cfg.param_counts()
    print(f"model: {cfg.name}  params={total/1e6:.1f}M  strategy={args.strategy}")

    mesh = make_host_mesh(data=4, model=2)
    overlap = OverlapConfig.from_args(args.overlap, args.overlap_groups)
    byz = ByzConfig.from_args(args.byz_attack, args.byz_fraction, args.byz_f)
    # one spec describes the whole gradient exchange: strategy, compressor,
    # bucketing, collective backend, and the overlap/byz/telemetry riders.
    # telemetry="full" records per-group EF-residual norms + densities in the
    # step records at no trajectory cost (bitwise-identical either way);
    # the dense baseline has no bucketed intermediates to read, so it stays off
    spec = CommSpec(
        strategy=args.strategy, compressor="scaled_sign",
        backend=args.backend, overlap=overlap, byz=byz,
        telemetry="off" if args.strategy == "dense" else "full",
    ).validate()
    job = TrainJob(
        cfg=cfg, mesh=mesh, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=0.01, optimizer="sgd", policy="tp",
        log_every=20, comm=spec,
    )

    # --overlap: report per step how much of the serial comm bill the
    # schedule leaves exposed. Fake-device collectives execute inline, so
    # the wire term is the analytic bucketed model at a 10 Gb/s reference
    # interconnect, pipelined against the MEASURED per-step compute time
    # (see repro.overlap.pipeline.exposure_report).
    exposure = None
    if overlap is not None and args.strategy in ("ef_allgather", "ef_ring"):
        import jax
        from repro.comm.bucketize import DEFAULT_BUCKET_SIZE, build_layout
        from repro.core.compressors import ScaledSignCompressor
        from repro.models import transformer
        from repro.overlap import build_schedule, proportional_exposure

        shapes = jax.eval_shape(
            lambda k: transformer.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        layout = build_layout(shapes, DEFAULT_BUCKET_SIZE)
        sched = build_schedule(layout, shapes, n_groups=overlap.n_groups)
        group_bytes = [g.wire_bytes for g in sched.groups]
        # (W−1) compressed payloads received per device @ 10 Gb/s reference
        # (TrainJob's default compressor is scaled_sign, matching the wire)
        peers = mesh.shape["data"] - 1
        wire_us = peers * layout.wire_bits(ScaledSignCompressor()) / 8.0 / 1250.0

        def exposure(step_wall_us):
            return proportional_exposure(
                group_bytes, max(step_wall_us - wire_us, 1.0), wire_us
            )

    last_wall = [0.0, 0]

    def log(rec):
        if exposure is not None and rec["step"] > last_wall[1]:
            d_steps = rec["step"] - last_wall[1]
            step_us = (rec["wall_s"] - last_wall[0]) / d_steps * 1e6
            rep = exposure(step_us)
            rec = dict(rec, comm_exposure_frac=round(rep["exposure_frac"], 4),
                       comm_exposed_us=round(rep["exposed_us"], 1),
                       comm_serial_us=round(rep["serial_comm_us"], 1))
        last_wall[0], last_wall[1] = rec["wall_s"], rec["step"]
        print(json.dumps(rec), flush=True)

    _, hist = run_training(job, log_fn=log)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f}; "
          f"wire bytes/step/device = {hist[-1]['wire_bytes']:.3g}; "
          f"corrected-gradient density φ = {hist[-1]['density']:.3f}")
    # telemetry="full" step records carry the per-bucket-group reads: the
    # paper's bounded EF-residual ||e_t|| and the per-group sign density
    if "err_l2" in hist[-1]:
        e0, e1 = hist[0]["err_l2"], hist[-1]["err_l2"]
        print(f"EF-residual L2 per group: {['%.3g' % x for x in e0]} -> "
              f"{['%.3g' % x for x in e1]}; "
              f"per-group density: {['%.3f' % x for x in hist[-1]['group_density']]}")
    # short smoke runs (< ~100 steps) don't move the loss at this model/batch
    # scale on ANY strategy (dense included) — only assert convergence on the
    # documented few-hundred-step horizon
    if args.steps >= 100:
        assert last < first


if __name__ == "__main__":
    main()
