"""Reproduce the paper's analytical experiments end-to-end (Figs. 1, 3, 5).

    PYTHONPATH=src python examples/paper_figures.py

Prints: CE1–CE3 outcomes (SIGNSGD fails / EF fixes), the §5.2 Wilson
least-squares generalization table (train/test loss + distance to gradient
span — Theorem IV), and the A.1 sparse-noise toy.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))


def main():
    from benchmarks import counterexamples, generalization, sparse_noise

    print("== §3 counterexamples (Fig. 1) ==")
    r1 = counterexamples.ce1()
    print(f"  CE1  f*=-0.25:  SGD f={r1['sgd']:+.3f}   SIGNSGD f={r1['signsgd']:+.3f} "
          f"(ascends!)   EF-SIGNSGD f={r1['ef_signsgd']:+.3f}")
    r2 = counterexamples.ce2()
    print(f"  CE2  f*=0:      SIGNSGD f={r2['signsgd_f']:.3f} (trapped on x1+x2="
          f"{r2['signsgd_line']:.3f})   EF-SIGNSGD f={r2['ef_signsgd_f']:.2e}")
    r3 = counterexamples.ce3()
    print(f"  CE3  f*=0:      SIGNSGD f={r3['signsgd_f']:.3f} (trapped a.s.)   "
          f"EF-SIGNSGD f={r3['ef_signsgd_f']:.2e}")

    print("\n== §5.2 Wilson over-parameterized least squares (Fig. 3) ==")
    res = generalization.run()
    print(f"  {'algo':12s} {'train':>9s} {'test':>9s} {'dist-to-span':>13s}")
    for name, r in res.items():
        print(f"  {name:12s} {r['train_loss']:9.2e} {r['test_loss']:9.3f} {r['span_dist']:13.3f}")
    assert res["ef_signsgd"]["test_loss"] < 0.3, "EF should generalize (≈ SGD)"
    assert res["signsgd"]["test_loss"] > res["ef_signsgd"]["test_loss"]

    print("\n== A.1 sparse-noise toy (Fig. 5) ==")
    sn = sparse_noise.run(reps=5)
    for name, (mean, std) in sn.items():
        print(f"  {name:16s} final f = {mean:10.2f} ± {std:.2f}")
    print("  (sign methods are FASTER here — the paper's point: the 'bad "
          "coordinate' story cannot explain real-data speed of EF)")


if __name__ == "__main__":
    main()
