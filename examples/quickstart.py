"""Quickstart: EF-SIGNSGD (paper Alg. 1) vs SGDM vs SIGNSGD on a tiny LM.

Runs three short training runs of the reduced llama3.2-1b config on synthetic
tokens and prints the loss trajectories plus the exact per-step wire bytes —
the paper's two headline claims (EF matches SGD; sign alone is worse;
communication shrinks ~32×) in one script.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_config, reduced
from repro.core.compressors import ScaledSignCompressor, tree_wire_bits, IdentityCompressor
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.train.loop import TrainJob, run_training


def main():
    cfg = reduced(get_config("llama3.2-1b"))
    mesh = make_host_mesh(data=1, model=1)

    results = {}
    for optimizer in ("sgdm", "signsgd", "ef_signsgd"):
        job = TrainJob(
            cfg=cfg, mesh=mesh, steps=60, batch=8, seq=64,
            lr=0.05 if optimizer != "sgdm" else 0.1,
            optimizer=optimizer, strategy="dense", log_every=20,
        )
        _, hist = run_training(job)
        results[optimizer] = [round(h["loss"], 3) for h in hist]
        print(f"{optimizer:12s} loss: {results[optimizer]}")

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    dense = tree_wire_bits(IdentityCompressor(), params)
    sign = tree_wire_bits(ScaledSignCompressor(), params)
    print(f"\nwire bits/step: dense fp32 = {dense:,}  EF-sign = {sign:,} "
          f"({dense / sign:.1f}x reduction — paper §6.1)")
    assert results["ef_signsgd"][-1] <= results["signsgd"][-1] + 0.05


if __name__ == "__main__":
    main()
