"""Compressor contracts (paper Assumption A) — hypothesis property tests.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); the whole
module skips cleanly when it is absent so tier-1 collection never fails.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.core import compressors as C

VECTORS = hnp.arrays(
    np.float32,
    st.integers(min_value=1, max_value=400),
    # no subnormals: XLA flushes denormals to zero (sign(−5e−42) → sign(0))
    elements=st.floats(-1e3, 1e3, width=32, allow_nan=False, allow_subnormal=False),
)


def _norm_sq(x):
    return float(jnp.sum(jnp.asarray(x, jnp.float32) ** 2))


@hypothesis.given(VECTORS)
@hypothesis.settings(deadline=None, max_examples=60)
def test_pack_unpack_roundtrip(x):
    xj = jnp.asarray(x)
    signs = C.unpack_signs(C.pack_signs(xj), x.shape[0])
    np.testing.assert_array_equal(np.asarray(signs) > 0, x >= 0)


@hypothesis.given(VECTORS)
@hypothesis.settings(deadline=None, max_examples=60)
def test_scaled_sign_is_density_compressor(x):
    """Lemma 8: ||C(v) − v||² ≤ (1 − φ(v))||v||² with φ = ||v||₁²/(d||v||₂²)."""
    xj = jnp.asarray(x)
    delta = C.ScaledSignCompressor().roundtrip(xj)
    phi = float(C.density(xj))
    assert 0.0 <= phi <= 1.0 + 1e-6
    assert _norm_sq(delta - xj) <= (1 - phi) * _norm_sq(xj) + 1e-3 * max(_norm_sq(xj), 1)


@hypothesis.given(VECTORS, st.integers(1, 64))
@hypothesis.settings(deadline=None, max_examples=60)
def test_topk_is_k_over_d_compressor(x, k):
    xj = jnp.asarray(x)
    comp = C.TopKCompressor(k=k)
    delta = comp.roundtrip(xj)
    d = x.shape[0]
    assert _norm_sq(delta - xj) <= (1 - comp.delta(d)) * _norm_sq(xj) + 1e-4 * max(_norm_sq(xj), 1)


@hypothesis.given(VECTORS)
@hypothesis.settings(deadline=None, max_examples=40)
def test_block_scaled_sign_contract(x):
    xj = jnp.asarray(x)
    comp = C.BlockScaledSignCompressor(block=64)
    delta = comp.roundtrip(xj)
    # per-block density δ ≥ global density, so at minimum the global holds
    assert _norm_sq(delta - xj) <= _norm_sq(xj) + 1e-3 * max(_norm_sq(xj), 1)


@hypothesis.given(VECTORS, st.integers(0, 2**31 - 1))
@hypothesis.settings(deadline=None, max_examples=40)
def test_randomk_expectation_contract(x, seed):
    hypothesis.assume(np.linalg.norm(x) > 1e-3)
    xj = jnp.asarray(x)
    comp = C.RandomKCompressor(k=8)
    # E||C(x)−x||² = (1−k/d)||x||² — check the average over keys
    errs = [
        _norm_sq(comp.roundtrip(xj, key=jax.random.PRNGKey(seed + i)) - xj)
        for i in range(20)
    ]
    bound = (1 - comp.delta(x.shape[0])) * _norm_sq(xj)
    assert np.mean(errs) <= bound * 1.35 + 1e-3


def test_qsgd_unbiased_and_ef_scaled_contract():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512,))
    comp = C.QSGDCompressor(s=15, ef_scaled=False)
    outs = jnp.stack(
        [comp.roundtrip(x, key=jax.random.PRNGKey(i)) for i in range(300)]
    )
    # unbiasedness of the raw quantizer
    np.testing.assert_allclose(np.asarray(jnp.mean(outs, 0)), np.asarray(x), atol=0.15)
    # Remark 5: U/k is a (1/k)-approximate compressor in expectation
    comp2 = C.QSGDCompressor(s=15, ef_scaled=True)
    k = comp2._k_factor(512)
    errs = [
        _norm_sq(comp2.roundtrip(x, key=jax.random.PRNGKey(i)) - x) for i in range(100)
    ]
    assert np.mean(errs) <= (1 - 1 / k) * _norm_sq(x) * 1.1


def test_low_rank_reconstructs_low_rank_matrices():
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (32, 2))
    v = jax.random.normal(jax.random.PRNGKey(1), (32, 2))
    m = (u @ v.T).reshape(-1)
    comp = C.LowRankCompressor(rank=2, iters=4)
    delta = comp.roundtrip(m)
    assert _norm_sq(delta - m) <= 1e-4 * _norm_sq(m)


def test_wire_bits_accounting():
    """The paper's Σ(dᵢ+32) bits for layer-wise scaled sign."""
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((7, 9))}
    comp = C.ScaledSignCompressor()
    bits = C.tree_wire_bits(comp, tree)
    # padded to 32-bit words: ceil(100/32)*32 + 32 + ceil(63/32)*32 + 32
    assert bits == (4 * 32 + 32) + (2 * 32 + 32)
    dense_bits = C.tree_wire_bits(C.IdentityCompressor(), tree)
    assert dense_bits == 32 * 163
    assert dense_bits / bits > 20  # ~32× for large tensors


def test_identity_is_delta_one():
    x = jnp.arange(37.0)
    assert _norm_sq(C.IdentityCompressor().roundtrip(x) - x) == 0.0


@pytest.mark.parametrize("name", ["scaled_sign", "sign", "top_k", "qsgd", "low_rank", "identity", "block_scaled_sign", "random_k"])
def test_registry(name):
    comp = C.get_compressor(name)
    x = jnp.linspace(-1, 1, 128)
    key = jax.random.PRNGKey(0) if not comp.deterministic else None
    out = comp.roundtrip(x, key=key)
    assert out.shape == x.shape
    assert comp.wire_bits(128) > 0
