"""Byzantine-robust aggregation (repro.comm.robust) and the fault-injection
layer (repro.comm.adversary): numpy oracles for the order-statistic
estimators, the byz_f=0 bitwise short-circuit to plain allgather decode,
tolerance validation at every seam, attack semantics, and the analytic
wire/decode-cost models.

Multi-worker trajectory equality runs in subprocesses (same isolation pattern
as tests/test_distributed.py) so the main pytest session keeps one CPU device.
Property-based coverage lives in tests/test_byzantine_props.py (optional
hypothesis dependency).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommSpec,
    adversary,
    bucketize,
    compressed,
    make_aggregator,
    robust,
)
from repro.configs.base import BYZ_ATTACKS, ByzConfig
from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, use_mesh

pytestmark = pytest.mark.byz

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# estimator oracles (numpy)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [3, 4, 5, 8])
def test_coord_median_matches_numpy(w):
    rng = np.random.default_rng(w)
    stack = jnp.asarray(rng.normal(size=(w, 3, 32)).astype(np.float32))
    got = np.asarray(robust.coord_median(stack))
    np.testing.assert_allclose(got, np.median(np.asarray(stack), axis=0), rtol=1e-6)


@pytest.mark.parametrize("w,f", [(3, 1), (5, 1), (5, 2), (8, 1), (8, 3)])
def test_trimmed_mean_matches_sorted_slice(w, f):
    rng = np.random.default_rng(10 * w + f)
    stack = jnp.asarray(rng.normal(size=(w, 2, 32)).astype(np.float32))
    got = np.asarray(robust.trimmed_mean(stack, f))
    want = np.sort(np.asarray(stack), axis=0)[f : w - f].mean(axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_trimmed_mean_f0_is_mean():
    rng = np.random.default_rng(0)
    stack = jnp.asarray(rng.normal(size=(4, 2, 32)).astype(np.float32))
    # allclose, not bitwise: the sorted reduction reassociates the sum
    np.testing.assert_allclose(
        np.asarray(robust.trimmed_mean(stack, 0)),
        np.asarray(stack).mean(axis=0),
        rtol=1e-5,
        atol=1e-6,
    )


def test_norm_filtered_mean_drops_far_worker():
    rng = np.random.default_rng(1)
    honest = rng.normal(size=(5, 2, 32)).astype(np.float32)
    stack = np.concatenate([honest, 100.0 + np.zeros((1, 2, 32), np.float32)])
    got = np.asarray(robust.norm_filtered_mean(jnp.asarray(stack), 1))
    np.testing.assert_allclose(got, honest.mean(axis=0), rtol=1e-5, atol=1e-6)


def test_norm_filtered_mean_catches_sign_flip():
    # a sign-flipped worker is norm-preserving; the distance-to-median
    # criterion still isolates it where a pure-norm filter could not
    rng = np.random.default_rng(2)
    base = rng.normal(size=(2, 32)).astype(np.float32)
    honest = base[None] + 0.01 * rng.normal(size=(7, 2, 32)).astype(np.float32)
    stack = np.concatenate([honest, -base[None]])
    got = np.asarray(robust.norm_filtered_mean(jnp.asarray(stack), 1))
    np.testing.assert_allclose(got, honest.mean(axis=0), rtol=1e-4, atol=1e-5)


def test_max_tolerance():
    assert [robust.max_tolerance(w) for w in (1, 2, 3, 4, 5, 8)] == [0, 0, 1, 1, 2, 3]


# ---------------------------------------------------------------------------
# robust_combine: the decode seam
# ---------------------------------------------------------------------------


def _gathered_payloads(w, nb=3, bs=64, seed=0):
    rng = np.random.default_rng(seed)
    comp = ScaledSignCompressor()
    enc = jax.vmap(lambda b, e: compressed.ef_encode_buckets(comp, b, e))
    b_w = jnp.asarray(rng.normal(size=(w, nb, bs)).astype(np.float32))
    e_w = jnp.asarray(rng.normal(size=(w, nb, bs)).astype(np.float32) * 0.1)
    payload_w, _, _ = enc(b_w, e_w)
    return comp, compressed.BucketPayload(data=payload_w.data), bs


@pytest.mark.parametrize("strategy", robust.ROBUST_STRATEGIES)
def test_robust_combine_f0_bitwise_equals_mean_decode(strategy):
    comp, gathered, bs = _gathered_payloads(4)
    mean = compressed.decode_mean_buckets(comp, gathered, bs)
    got = robust.robust_combine(strategy, comp, gathered, bs, byz_f=0)
    assert np.array_equal(np.asarray(got), np.asarray(mean)), (
        "byz_f=0 must short-circuit to the literal allgather decode"
    )


def test_robust_combine_estimators_match_decoded_stack():
    comp, gathered, bs = _gathered_payloads(5)
    stack = np.asarray(compressed.decode_buckets_stack(comp, gathered, bs))
    np.testing.assert_allclose(
        np.asarray(robust.robust_combine("ef_coord_median", comp, gathered, bs, byz_f=1)),
        np.median(stack, axis=0),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(robust.robust_combine("ef_trimmed_mean", comp, gathered, bs, byz_f=2)),
        np.sort(stack, axis=0)[2:3].mean(axis=0),
        rtol=1e-5,
        atol=1e-6,
    )


def test_robust_combine_rejects_unknown_strategy():
    comp, gathered, bs = _gathered_payloads(4)
    with pytest.raises(ValueError):
        robust.robust_combine("ef_mystery", comp, gathered, bs, byz_f=1)


def test_decode_buckets_stack_rows_match_single_decode():
    comp, gathered, bs = _gathered_payloads(3)
    stack = compressed.decode_buckets_stack(comp, gathered, bs)
    for i in range(3):
        row = compressed.BucketPayload(data=jax.tree.map(lambda x: x[i], gathered.data))
        np.testing.assert_array_equal(
            np.asarray(stack[i]),
            np.asarray(compressed.decode_buckets(comp, row, bs)),
        )


# ---------------------------------------------------------------------------
# tolerance validation at every seam
# ---------------------------------------------------------------------------


def test_validate_tolerance_breakdown_point():
    robust.validate_tolerance("ef_coord_median", 1, 4)  # 2f < W: fine
    robust.validate_tolerance("ef_allgather", 0, 2)
    with pytest.raises(ValueError, match="0 <= byz_f <= 1"):
        robust.validate_tolerance("ef_coord_median", 2, 4)
    with pytest.raises(ValueError, match="0 <= byz_f <= 0"):
        robust.validate_tolerance("ef_trimmed_mean", 1, 2)
    with pytest.raises(ValueError):
        robust.validate_tolerance("ef_norm_filter", -1, 8)
    with pytest.raises(ValueError, match="robust"):
        robust.validate_tolerance("ef_allgather", 1, 8)


def test_make_aggregator_rejects_breakdown():
    mesh = make_host_mesh(data=1, model=1)
    layout = bucketize.build_layout({"x": jnp.zeros((256,), jnp.float32)}, 128)
    spec = CommSpec(
        strategy="ef_coord_median",
        compressor=ScaledSignCompressor(),
        bucket_size=128,
        byz=ByzConfig(f=1),
    )
    with use_mesh(mesh):
        with pytest.raises(ValueError, match="0 <= byz_f <= 0"):
            make_aggregator(spec, layout, mesh, ("data",))


def test_robust_strategies_rejected_on_per_leaf_path():
    with pytest.raises(ValueError, match="bucketed-only"):
        aggregation.init_agg_state(
            "ef_coord_median", {"x": jnp.zeros(8)}, world=4, bucket_size=None
        )


def test_train_step_rejects_byz_without_buckets():
    from repro.train import steps as ST

    with pytest.raises(ValueError, match="bucketed"):
        ST.make_train_step(
            None,
            None,
            None,
            strategy="dense",
            comp=None,
            local_chain=None,
            ef_axes=(),
            batch_example=None,
            state_example=None,
            bucket_size=None,
            byz=ByzConfig(),
        )


# ---------------------------------------------------------------------------
# ByzConfig
# ---------------------------------------------------------------------------


def test_byz_config_validation():
    with pytest.raises(ValueError):
        ByzConfig(attack="meteor_strike")
    with pytest.raises(ValueError):
        ByzConfig(fraction=1.0)
    with pytest.raises(ValueError):
        ByzConfig(fraction=-0.1)
    with pytest.raises(ValueError):
        ByzConfig(f=-1)
    assert ByzConfig(attack="zero_out", fraction=0.25).attack in BYZ_ATTACKS


def test_byz_config_from_args():
    assert ByzConfig.from_args(None, None, None) is None
    c = ByzConfig.from_args("sign_flip", None, None)
    assert c.attack == "sign_flip" and c.fraction == 0.0 and c.f == 0
    c = ByzConfig.from_args(None, 0.25, 1, 3.0)
    assert c.fraction == 0.25 and c.f == 1 and c.scale == 3.0
    assert ByzConfig.from_args(None, None, 2).f == 2


# ---------------------------------------------------------------------------
# adversary: fault injection semantics
# ---------------------------------------------------------------------------


def _tree_w(w=4, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(w, 7)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(w, 3, 5)).astype(np.float32)),
    }


def test_n_attackers_floor():
    assert adversary.n_attackers(0.0, 8) == 0
    assert adversary.n_attackers(1 / 8, 8) == 1
    assert adversary.n_attackers(0.24, 8) == 1
    assert adversary.n_attackers(0.25, 8) == 2
    assert adversary.n_attackers(0.49, 2) == 0


def test_zero_attackers_is_identity_object():
    tree = _tree_w()
    byz = ByzConfig(attack="sign_flip", fraction=0.1)  # floor(0.4) = 0
    out = adversary.corrupt_worker_tree(byz, tree, jax.random.PRNGKey(0), world=4)
    assert out is tree, "0 attackers must be a python-level no-op"


@pytest.mark.parametrize("attack", BYZ_ATTACKS)
def test_honest_lanes_bitwise_untouched(attack):
    tree = _tree_w()
    byz = ByzConfig(attack=attack, fraction=0.5)  # lanes 0,1 of 4
    out = adversary.corrupt_worker_tree(byz, tree, jax.random.PRNGKey(0), world=4)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k][2:]), np.asarray(tree[k][2:]))


def test_attack_semantics():
    tree = _tree_w()
    key = jax.random.PRNGKey(0)
    flip = adversary.corrupt_worker_tree(
        ByzConfig(attack="sign_flip", fraction=0.5), tree, key, world=4
    )
    np.testing.assert_array_equal(np.asarray(flip["a"][:2]), -np.asarray(tree["a"][:2]))
    zero = adversary.corrupt_worker_tree(
        ByzConfig(attack="zero_out", fraction=0.5), tree, key, world=4
    )
    assert not np.any(np.asarray(zero["b"][:2]))
    drift = adversary.corrupt_worker_tree(
        ByzConfig(attack="const_drift", fraction=0.5, scale=3.5), tree, key, world=4
    )
    np.testing.assert_array_equal(np.asarray(drift["a"][:2]), np.full((2, 7), 3.5))
    # colluding: every adversarial lane submits the identical vector
    np.testing.assert_array_equal(np.asarray(drift["b"][0]), np.asarray(drift["b"][1]))
    noise = adversary.corrupt_worker_tree(
        ByzConfig(attack="scaled_noise", fraction=0.5, scale=10.0), tree, key, world=4
    )
    assert float(np.abs(np.asarray(noise["a"][:2])).mean()) > 2.0
    assert not np.array_equal(np.asarray(noise["a"][0]), np.asarray(noise["a"][1]))


# ---------------------------------------------------------------------------
# in-process aggregator: robust strategies on the W=1 collective path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", robust.ROBUST_STRATEGIES)
def test_bucketed_aggregator_robust_single_device(strategy):
    mesh = make_host_mesh(data=1, model=1)
    tree = {"x": jnp.linspace(-1, 1, 300, dtype=jnp.float32)}
    layout = bucketize.build_layout(tree, 128)
    comp = ScaledSignCompressor()
    buckets_w = tuple(b[None] for b in bucketize.flatten_buckets(layout, tree))
    err = tuple(jnp.ones_like(b) * 0.1 for b in buckets_w)
    key = jax.random.PRNGKey(0)
    with use_mesh(mesh):
        spec_ag = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=128)
        spec_rb = CommSpec(strategy=strategy, compressor=comp, bucket_size=128)
        ag = jax.jit(make_aggregator(spec_ag, layout, mesh, ("data",)))
        rb = jax.jit(make_aggregator(spec_rb, layout, mesh, ("data",)))
        o1, o2 = ag(buckets_w, err, (), key), rb(buckets_w, err, (), key)
    # W=1, byz_f=0: identical payloads, identical decode → bitwise equal,
    # and the robust strategies bill exactly the allgather wire bytes
    for a, b in zip(o1[0] + o1[1], o2[0] + o2[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(o1[3].wire_bytes_per_device) == float(o2[3].wire_bytes_per_device)


# ---------------------------------------------------------------------------
# analytic models
# ---------------------------------------------------------------------------


def test_robust_wire_model_equals_allgather():
    for w in (1, 2, 8, 16):
        assert aggregation.bucketed_sign_robust_wire_bytes(
            12, 1024, w
        ) == aggregation.bucketed_sign_allgather_wire_bytes(12, 1024, w)


def test_robust_decode_cost_model():
    d = 4 * 256
    m = aggregation.robust_decode_cost_model(4, 256, 8, byz_f=1, kind="ef_coord_median")
    assert m["stack_hbm_bytes"] == 4.0 * 8 * d
    assert m["sort_flops"] == d * 8 * 3  # log2(8) = 3
    assert m["reduce_flops"] == d
    assert m["total_flops"] == m["sort_flops"] + m["reduce_flops"]
    tm = aggregation.robust_decode_cost_model(4, 256, 8, byz_f=2, kind="ef_trimmed_mean")
    assert tm["reduce_flops"] == d * (8 - 4)
    assert aggregation.robust_decode_cost_model(4, 256, 1)["sort_flops"] == 0
    with pytest.raises(ValueError):
        aggregation.robust_decode_cost_model(4, 256, 8, kind="ef_mystery")


# ---------------------------------------------------------------------------
# multi-worker subprocesses
# ---------------------------------------------------------------------------

_TRAJ_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST

W = %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=W, model=1)
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)

def run(strategy):
    with use_mesh(mesh):
        state = init_train_state(cfg, key, chain, strategy, mesh, ef_axes, bucket_size=4096)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
        bundle = ST.make_train_step(cfg, mesh, rules, strategy=strategy,
            comp=ScaledSignCompressor(), local_chain=chain, ef_axes=ef_axes,
            batch_example=batch, state_example=state, bucket_size=4096)
        state = jax.device_put(state, bundle.in_shardings[0])
        batch = jax.device_put(batch, bundle.in_shardings[1])
        fn = bundle.jit()
        traj = []
        for _ in range(5):
            state, (loss, m) = fn(state, batch)
            traj.append(float(loss))
        return traj, jax.device_get(jax.tree.leaves(state.params)), float(m["wire_bytes"])

t0, p0, w0 = run("ef_allgather")
out = {"traj": t0, "robust": {}}
for s in ("ef_coord_median", "ef_trimmed_mean", "ef_norm_filter"):
    t, p, w = run(s)
    out["robust"][s] = {
        "traj_equal": t == t0,
        "params_equal": all(np.array_equal(a, b) for a, b in zip(p, p0)),
        "wire_equal": w == w0,
    }
print(json.dumps(out))
"""

_ATTACK_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.configs.base import ByzConfig
from repro.launch.mesh import make_host_mesh
from repro.train.loop import TrainJob, run_training

cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=4, model=1)
byz = ByzConfig(attack="sign_flip", fraction=0.25, f=1)
job = TrainJob(cfg=cfg, mesh=mesh, steps=8, batch=8, seq=32, lr=0.02,
               optimizer="ef_signsgd", strategy="ef_trimmed_mean",
               bucket_size=4096, byz=byz, log_every=1)
_, hist = run_training(job)
print(json.dumps({"losses": [h["loss"] for h in hist]}))
"""


def _run_driver(code_tmpl, **kw):
    code = code_tmpl % {"repo": REPO, **kw}
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("world", [2, 4])
def test_robust_strategies_bitwise_at_zero_attackers(world):
    """The ISSUE acceptance gate: with attackers=0 and byz_f=0 every robust
    strategy reproduces ef_allgather's 5-step trajectory bitwise."""
    out = _run_driver(_TRAJ_DRIVER, world=world)
    for s, r in out["robust"].items():
        assert r["traj_equal"], f"W={world} {s}: losses diverged from {out['traj']}"
        assert r["params_equal"], f"W={world} {s}: params diverged"
        assert r["wire_equal"], f"W={world} {s}: wire bill must match allgather"


@pytest.mark.slow
def test_attacked_robust_run_still_trains():
    out = _run_driver(_ATTACK_DRIVER)
    losses = out["losses"]
    assert losses[-1] < losses[0], losses
