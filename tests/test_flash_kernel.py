"""Flash-attention Pallas kernel vs the XLA chunked-attention oracle —
interpret-mode shape/dtype/mask sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L


@pytest.mark.parametrize(
    "b,sq,sk,h,d,causal,window",
    [
        (2, 128, 128, 4, 64, True, 0),
        (1, 200, 200, 2, 64, True, 0),  # non-multiple lengths (padding path)
        (2, 128, 128, 4, 64, True, 48),  # sliding window
        (1, 100, 260, 2, 64, False, 0),  # cross-attention, Sq != Sk
        (1, 64, 64, 1, 128, True, 0),  # head_dim 128
    ],
)
def test_flash_matches_oracle(b, sq, sk, h, d, causal, window):
    key = jax.random.PRNGKey(sq * 7 + sk)
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, h, d))
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    ref = L.chunked_attention(q, k, v, causal=causal, window=window, chunk=64, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_bf16_io():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 128, 2, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    assert out.dtype == jnp.bfloat16
    ref = L.chunked_attention(q, k, v, causal=True, chunk=64, q_chunk=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )


# --------------------------- decode kernel -------------------------------- #


@pytest.mark.parametrize(
    "b,t,h,d,pos,ring_full",
    [
        (2, 256, 4, 64, 100, False),  # prefix-valid cache
        (1, 300, 2, 64, 299, False),  # non-multiple T (padding path)
        (2, 128, 4, 64, 500, True),  # wrapped ring buffer — all slots valid
        (1, 512, 8, 128, 0, False),  # single valid slot
    ],
)
def test_flash_decode_matches_oracle(b, t, h, d, pos, ring_full):
    from repro.kernels.flash_decode import flash_decode

    key = jax.random.PRNGKey(t + pos)
    q = jax.random.normal(key, (b, 1, h, d))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, t, h, d))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, t, h, d))
    out = flash_decode(q, kc, vc, pos, ring_full=ring_full, block_t=64, interpret=True)

    slots = jnp.arange(t)
    valid = jnp.broadcast_to(
        jnp.ones((t,), bool) if ring_full else slots <= pos, (b, t)
    )
    ref = L.decode_attention(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
