"""The paper's §3 counterexamples, executed — SIGNSGD fails, EF-SIGNSGD fixes.

These are paper-faithful validations (benchmarks/counterexamples.py renders
the full tables; here we assert the qualitative claims).
"""

import jax
import jax.numpy as jnp
import numpy as np


def _sgn(x):
    # the paper's sign operator: sign(0) = +1 (matches our compressors)
    return jnp.where(x >= 0, 1.0, -1.0)


def test_counterexample_1_signsgd_ascends_in_expectation():
    """CE1: f(x)=x/4 on [-1,1]; g=4 w.p. 1/4, −1 w.p. 3/4.
    E[sign(g)] = −1/2 → SIGNSGD moves x UP (f increases); SGD moves down."""
    # exact expectations, no sampling needed
    e_g = 0.25 * 4 + 0.75 * (-1)  # = 1/4 = ∇f
    assert abs(e_g - 0.25) < 1e-12
    e_sign = 0.25 * 1 + 0.75 * (-1)  # = −1/2
    gamma = 0.1
    # SGD: E[f(x − γ g)] − f(x) = −γ/16
    assert -gamma * e_g / 4 < 0
    # SIGNSGD: E[f(x − γ sign g)] − f(x) = +γ/8
    assert -gamma * e_sign / 4 > 0

    # and empirically over the stochastic process (numpy: the dynamics are
    # scalar, so a long horizon is cheap — the SGD chain mixes slowly and a
    # short window straddles the stationary mean of ≈ −0.1)
    for stepper, expect_down in [("sgd", True), ("sign", False)]:
        rng = np.random.default_rng(0)
        x = 0.0
        fs = []
        for i in range(20000):
            g = 4.0 if rng.uniform() < 0.25 else -1.0
            step = g if stepper == "sgd" else (1.0 if g >= 0 else -1.0)
            x = float(np.clip(x - gamma * step, -1.0, 1.0))
            if i >= 5000:
                fs.append(x / 4)
        f = float(np.mean(fs))  # time-average beats endpoint noise (±γ jumps)
        # the claim is directional: E[f] decreases under SGD, increases under
        # sign (boundary clipping keeps the stationary mean off ±0.25)
        if expect_down:
            assert f < -0.05, f
        else:
            assert f > 0.15, f


def _ce2_grad(x, eps=0.5):
    # subgradient with the paper's sign(0)=+1 choice — at x₁=x₂ the
    # adversarial subgradient keeps sign(g)=±(1,−1) (paper §3, CE2)
    s1 = _sgn(x[0] + x[1])
    s2 = _sgn(x[0] - x[1])
    return s1 * eps * jnp.array([1.0, 1.0]) + s2 * jnp.array([1.0, -1.0])


def test_counterexample_2_signsgd_stuck_ef_converges():
    """CE2: f = ε|x₁+x₂| + |x₁−x₂|, full subgradient. SIGNSGD iterates stay on
    the line x₁+x₂=2; EF-SIGNSGD reaches the optimum (0,0)."""
    eps = 0.5
    f = lambda x: eps * jnp.abs(x[0] + x[1]) + jnp.abs(x[0] - x[1])

    # SIGNSGD with decreasing steps
    x = jnp.array([1.0, 1.0])
    for t in range(400):
        g = _ce2_grad(x, eps)
        x = x - 0.05 / np.sqrt(t + 1) * _sgn(g)
    assert abs(float(x[0] + x[1]) - 2.0) < 1e-4  # trapped on the line
    assert float(f(x)) >= float(f(jnp.array([1.0, 1.0]))) - 1e-5

    # EF-SIGNSGD (Algorithm 1)
    from repro.core import ScaledSignCompressor, ef_step, init_ef_state

    comp = ScaledSignCompressor()
    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    for t in range(400):
        g = _ce2_grad(x, eps)
        out, state = ef_step(comp, {"x": -0.05 * g}, state)
        x = x + out["x"]
    assert float(f(x)) < 0.15, float(f(x))


def test_counterexample_3_stochastic_least_squares():
    """CE3: f = ⟨a₁,x⟩² + ⟨a₂,x⟩², aᵢ = ±(1,−1) + ε(1,1); batch-1 stochastic
    gradients have sign ±(1,−1) → SIGNSGD trapped a.s.; EF-SIGNSGD escapes."""
    eps = 0.5
    a1 = jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    a2 = -jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    f = lambda x: jnp.dot(a1, x) ** 2 + jnp.dot(a2, x) ** 2

    def stoch_grad(x, key):
        a = jnp.where(jax.random.uniform(key) < 0.5, 1.0, 0.0)
        ai = a * a1 + (1 - a) * a2
        return 2 * jnp.dot(ai, x) * ai * 2  # ×2: unbiased for the sum

    key = jax.random.PRNGKey(0)
    x = jnp.array([1.0, 1.0])
    for t in range(600):
        key, sub = jax.random.split(key)
        x = x - 0.02 / np.sqrt(t + 1) * _sgn(stoch_grad(x, sub))
    assert abs(float(x[0] + x[1]) - 2.0) < 1e-4  # trapped
    f_sign = float(f(x))

    from repro.core import ScaledSignCompressor, ef_step, init_ef_state

    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    key = jax.random.PRNGKey(0)
    for t in range(600):
        key, sub = jax.random.split(key)
        g = stoch_grad(x, sub)
        out, state = ef_step(ScaledSignCompressor(), {"x": -0.02 * g}, state)
        x = x + out["x"]
    assert float(f(x)) < 0.1 * f_sign, (float(f(x)), f_sign)


def test_theorem_1_sign_pattern():
    """Theorem I precondition: sign(gradient) = ±s for rank-1 data —
    the iterates only ever move along one diagonal."""
    key = jax.random.PRNGKey(0)
    s = jnp.sign(jax.random.normal(key, (8,)))
    for i in range(20):
        ai = s * jnp.abs(jax.random.normal(jax.random.PRNGKey(i), (8,)))  # sign(aᵢ)=s
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (8,))
        g = ai * jnp.dot(ai, x)  # ∇ of ½⟨aᵢ,x⟩²
        assert (
            np.array_equal(np.sign(np.asarray(g)), np.asarray(s))
            or np.array_equal(np.sign(np.asarray(g)), -np.asarray(s))
        )
