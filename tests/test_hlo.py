"""Trip-count-aware HLO analyzer: validated against known-FLOPs programs
(this is what the roofline numbers stand on)."""

import jax
import jax.numpy as jnp

from repro.utils import hlo as H


def _flops(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return H.analyze(txt)["dot_flops"]


def test_plain_dot():
    x = jnp.ones((32, 48))
    w = jnp.ones((48, 16))
    assert _flops(lambda a, b: a @ b, x, w) == 2 * 32 * 48 * 16


def test_scan_multiplies_trip_count():
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))

    def f(x, w):
        def body(c, _):
            return c @ w, ()
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    assert _flops(f, x, w) == 7 * 2 * 64**3


def test_nested_scans_multiply():
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))

    def g(x, w):
        def inner(c, _):
            return c @ w, ()

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, ()

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    assert _flops(g, x, w) == 15 * 2 * 64**3


def test_attention_flops_exact():
    from repro.models import layers as L

    b, s, hq, hkv, dh = 1, 64, 4, 2, 16
    q = jnp.ones((b, s, hq, dh))
    k = jnp.ones((b, s, hkv, dh))
    v = jnp.ones((b, s, hkv, dh))
    f = lambda q, k, v: L.chunked_attention(q, k, v, causal=True, chunk=16, q_chunk=16)
    # qkᵀ + pv over all (q,kv) blocks (masked-full baseline): 2 · 2·B·H·S²·D
    assert _flops(f, q, k, v) == 2 * 2 * b * hq * s * s * dh


def test_grad_flops_roughly_3x_forward():
    w = jnp.ones((64, 64))
    x = jnp.ones((8, 64))

    fwd = _flops(lambda w: jnp.sum(x @ w), w)
    bwd = _flops(jax.grad(lambda w: jnp.sum((x @ w) ** 2)), w)
    assert bwd >= 2 * fwd  # dx and dw matmuls


def test_shape_bytes():
    assert H.shape_bytes("f32[4,8]") == 128
    assert H.shape_bytes("(s32[], bf16[2,3])") == 4 + 12
    assert H.shape_bytes("u32[16]{0}") == 64
