"""Substrate tests: data generators, sharding rules, checkpointing, optimizer
schedules, and the 1-device training loop."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import optim
from repro.data import synthetic
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.utils import compat
from repro.models import transformer as T
from repro.sharding.rules import ShardingRules, default_policy
from repro.train import checkpoint as ckpt


# ------------------------------- data ------------------------------------ #


def test_wilson_data_matches_a6_spec():
    d = synthetic.wilson_least_squares(seed=3)
    a = np.vstack([d.a_train, d.a_test])
    y = np.concatenate([d.y_train, d.y_test])
    n = len(y)
    assert a.shape == (200, 1200) and n == 200
    assert set(np.unique(y)) == {-1.0, 1.0}
    # per-row structure: col0 = y, col1..2 = 1, then 1 or 3 slots of 1s
    for i in np.random.default_rng(0).choice(200, 20, replace=False):
        # rows were shuffled; identify by the unique block position instead
        row = a[i]
        assert row[1] == 1.0 and row[2] == 1.0
        width = int(row[3:].sum())
        # A.6: slots 4+5(i−1) … 4+5(i−1)+2(1−yᵢ) → 1 slot (y=+1) or 5 (y=−1)
        assert width in (1, 5)
        assert row[0] == (1.0 if width == 1 else -1.0)


def test_token_batches_deterministic_and_learnable():
    it1 = synthetic.token_batches(0, 4, 32, 128)
    it2 = synthetic.token_batches(0, 4, 32, 128)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token aligned
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"][:, 1:]), np.asarray(b1["labels"][:, :-1])
    )


def test_proxy_classification_separates_noise():
    (xtr, ytr), (xte, yte) = synthetic.proxy_classification(seed=0)
    assert xtr.shape[0] == 4096 and xte.shape[0] == 1024
    assert 0 <= ytr.min() and ytr.max() < 10


# ----------------------------- sharding ---------------------------------- #


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("policy", ["tp", "fsdp"])
def test_param_specs_divisible(arch, policy):
    """Every spec axis must evenly divide its dim on the production mesh
    shape (checked abstractly against 16×16 sizes)."""
    cfg = get_config(arch)
    mesh = make_host_mesh(data=1, model=1)  # host mesh; sizes faked below
    rules = ShardingRules(cfg, mesh, policy)
    rules.model_size, rules.data_size = 16, 16  # production sizes

    params = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
    specs = rules.param_specs(params)

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            axes = entry if isinstance(entry, tuple) else ((entry,) if entry else ())
            size = 1
            for a in axes:
                size *= {"model": 16, "data": 16, None: 1}.get(a, 1)
            assert dim % size == 0, (arch, policy, path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs
    )


def test_vocab_padding():
    cfg = get_config("granite_moe_1b_a400m")
    assert cfg.vocab_size == 49155
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_default_policy_by_size():
    assert default_policy(get_config("llama3_2_1b")) == "tp"
    assert default_policy(get_config("jamba_1_5_large_398b")) == "fsdp"


# ---------------------------- checkpoint --------------------------------- #


def test_checkpoint_roundtrip_and_latest():
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "step": jnp.int32(7),
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, state, 10)
        ckpt.save_checkpoint(d, jax.tree.map(lambda x: x * 2, state), 20)
        assert ckpt.latest_step(d) == 20
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        r10 = ckpt.restore_checkpoint(d, like, step=10)
        r20 = ckpt.restore_checkpoint(d, like)
        np.testing.assert_allclose(np.asarray(r10["params"]["w"]) * 2,
                                   np.asarray(r20["params"]["w"]))


# ---------------------------- schedules ---------------------------------- #


def test_step_decay_schedule_decimates():
    sched = optim.step_decay_schedule(1.0, 200)
    assert float(sched(jnp.int32(0))) == 1.0
    assert abs(float(sched(jnp.int32(120))) - 0.1) < 1e-6
    assert abs(float(sched(jnp.int32(180))) - 0.01) < 1e-7


def test_signum_matches_paper_recursion():
    """m_{t+1} = g_t + β m_t (NOT an EMA) — check two steps by hand."""
    opt = optim.signum(1.0, beta=0.5)
    p = {"x": jnp.zeros((2,))}
    st = opt.init(p)
    u1, st = opt.update({"x": jnp.array([1.0, -2.0])}, st, p)
    np.testing.assert_allclose(np.asarray(u1["x"]), [-1.0, 1.0])
    # m = [1,-2]; next g=[0.4,3] → m = [0.9, 2.0] → update = −sign = [-1,-1]
    u2, st = opt.update({"x": jnp.array([0.4, 3.0])}, st, p)
    np.testing.assert_allclose(np.asarray(u2["x"]), [-1.0, -1.0])


# ------------------------- 1-device training loop ------------------------ #


@pytest.mark.xfail(
    compat.OLD_JAX,
    reason="25-step ef_signsgd loss decrease is marginal and misses under the "
    "0.4.x RNG stream (re-probed 2026-08-09 on the 0.4.37 pin: loss 6.9823 vs "
    "6.9276, still short — marker stays); converges on longer horizons",
    strict=False,
)
def test_training_loop_reduces_loss_and_checkpoints():
    from repro.train.loop import TrainJob, run_training

    cfg = reduced(get_config("llama3_2_1b"))
    mesh = make_host_mesh(data=1, model=1)
    with tempfile.TemporaryDirectory() as d:
        job = TrainJob(
            cfg=cfg, mesh=mesh, steps=25, batch=4, seq=48, lr=0.08,
            optimizer="ef_signsgd", strategy="dense", log_every=5,
            ckpt_dir=d, ckpt_every=20,
        )
        state, hist = run_training(job)
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert ckpt.latest_step(d) == 20


def test_microbatch_gradient_accumulation_exact():
    """M-way gradient accumulation ≡ single full-batch step (fp32)."""
    import dataclasses

    from repro.train import steps as ST
    from repro.train.state import init_train_state

    cfg = dataclasses.replace(
        reduced(get_config("llama3_2_1b")), param_dtype="float32", compute_dtype="float32"
    )
    mesh = make_host_mesh(data=1, model=1)
    rules = ShardingRules(cfg, mesh, "dp")
    chain = optim.sgd(0.05)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
    }
    outs = {}
    with use_mesh(mesh):
        for m in (1, 4):
            state = init_train_state(cfg, key, chain, "dense", mesh, ())
            b = ST.make_train_step(
                cfg, mesh, rules, strategy="dense", local_chain=chain, ef_axes=(),
                batch_example=batch, state_example=state, microbatches=m,
            )
            st2, (loss, _) = b.jit()(state, batch)
            outs[m] = (float(loss), np.asarray(jax.tree.leaves(st2.params)[0]))
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=1e-5)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-4, atol=1e-6)
