"""Property-based contracts for the federated tier's EF invariants.

The three properties the ISSUE pins (``hypothesis`` is an optional dev
dependency — the module skips cleanly when absent, the deterministic coverage
in tests/test_fed.py still runs):

1. EF conservation: over any gradient sequence, the decoded updates plus the
   final residual telescope back to the raw gradient sum — for ANY compressor
   (``e' + C⁻¹(C(p)) == p == u + e`` exactly, so the sum is conserved).
2. Skip-k equivalence: a client's payload is a pure function of (update,
   residual row); rows of non-sampled clients are carried bitwise, so a
   client that skipped k rounds contributes exactly what it would have
   contributed immediately.
3. FedAvg weights are permutation-equivariant, normalized, and nonnegative.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.comm import compressed
from repro.core.compressors import (
    BlockScaledSignCompressor,
    ScaledSignCompressor,
    TopKCompressor,
)
from repro.fed import dataset_weights
from repro.fed import server as fed_server

pytestmark = pytest.mark.fed

_BS = 32  # sign kernels need bucket_size % 32 == 0

COMPRESSORS = st.sampled_from(
    [ScaledSignCompressor(), BlockScaledSignCompressor(block=8), TopKCompressor(k=8)]
)

GRAD_SEQS = hnp.arrays(
    np.float32,
    st.tuples(st.integers(1, 5), st.integers(1, 4)).map(lambda t: (*t, _BS)),
    # no subnormals: XLA flushes denormals to zero
    elements=st.floats(-100.0, 100.0, width=32, allow_nan=False, allow_subnormal=False),
)


@hypothesis.given(COMPRESSORS, GRAD_SEQS)
@hypothesis.settings(deadline=None, max_examples=25)
def test_ef_conservation_over_any_gradient_sequence(comp, grads):
    # sum of applied (decoded) updates + final residual == sum of raw
    # gradients, per dtype group — the paper's "no gradient is ever lost"
    rounds, nb = grads.shape[0], grads.shape[1]
    err = jnp.zeros((nb, _BS), jnp.float32)
    applied = np.zeros((nb, _BS), np.float64)
    for t in range(rounds):
        payload, err, _ = compressed.ef_encode_buckets(comp, jnp.asarray(grads[t]), err)
        applied += np.asarray(compressed.decode_buckets(comp, payload, _BS), np.float64)
    total = applied + np.asarray(err, np.float64)
    want = grads.astype(np.float64).sum(axis=0)
    scale = np.abs(grads.astype(np.float64)).sum(axis=0).max() + 1.0
    np.testing.assert_allclose(total, want, atol=2e-4 * scale)


@hypothesis.given(
    COMPRESSORS,
    hnp.arrays(np.float32, (3, _BS),
               elements=st.floats(-100.0, 100.0, width=32, allow_nan=False,
                                  allow_subnormal=False)),
    hnp.arrays(np.float32, (3, _BS),
               elements=st.floats(-10.0, 10.0, width=32, allow_nan=False,
                                  allow_subnormal=False)),
    st.integers(1, 6),
    st.integers(0, 7),
)
@hypothesis.settings(deadline=None, max_examples=25)
def test_skip_k_rounds_then_participate_is_bitwise_equivalent(comp, u, e, k, target):
    # pool semantics: k rounds that never sample `target` carry its row
    # bitwise; the payload it then ships equals the immediate encode
    n, nb = 8, u.shape[0]
    key = jax.random.PRNGKey(0)
    pool = (jax.random.normal(key, (n, nb, _BS), jnp.float32),)
    pool = fed_server.scatter_rows(pool, jnp.asarray([target]), (jnp.asarray(e)[None],))
    row0 = np.asarray(pool[0][target])
    others = [i for i in range(n) if i != target]
    for r in range(k):
        idx = jnp.asarray(others[r % len(others) : r % len(others) + 2], jnp.int32)
        fresh = jnp.full((idx.shape[0], nb, _BS), float(r + 1), jnp.float32)
        pool = fed_server.scatter_rows(pool, idx, (fresh,))
    np.testing.assert_array_equal(np.asarray(pool[0][target]), row0)
    direct_pay, direct_err, _ = compressed.ef_encode_buckets(
        comp, jnp.asarray(u), jnp.asarray(e)
    )
    late_err_row = fed_server.gather_rows(pool, jnp.asarray([target]))[0][0]
    late_pay, late_err, _ = compressed.ef_encode_buckets(comp, jnp.asarray(u), late_err_row)
    for a, b in zip(jax.tree.leaves(direct_pay), jax.tree.leaves(late_pay)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(direct_err), np.asarray(late_err))


@hypothesis.given(
    st.lists(st.integers(1, 10_000), min_size=1, max_size=32),
    st.randoms(use_true_random=False),
)
@hypothesis.settings(deadline=None)
def test_dataset_weights_permutation_equivariant_and_normalized(sizes, rng):
    sizes = np.asarray(sizes, np.float32)
    perm = np.asarray(rng.sample(range(len(sizes)), len(sizes)))
    w = np.asarray(dataset_weights(jnp.asarray(sizes)), np.float64)
    wp = np.asarray(dataset_weights(jnp.asarray(sizes[perm])), np.float64)
    assert (w >= 0.0).all() and (wp >= 0.0).all()
    assert w.sum() == pytest.approx(1.0, abs=1e-5)
    # permuting clients permutes their weights (up to summation-order ulps)
    np.testing.assert_allclose(wp, w[perm], rtol=1e-5)
    # weights are scale-invariant: only relative sizes matter
    w2 = np.asarray(dataset_weights(jnp.asarray(sizes * 4.0)), np.float64)
    np.testing.assert_allclose(w2, w, rtol=1e-5)
