"""Async overlap subsystem: scheduler determinism, ring-vs-allgather
equivalence for every compressor, and bitwise trajectory equality of the
overlapped EF step against the one-shot bucketed step.

Multi-worker cases run in subprocesses (same isolation pattern as
tests/test_distributed.py) so the main pytest session keeps one CPU device.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommSpec, bucketize, make_aggregator
from repro.core.compressors import ScaledSignCompressor, density
from repro.kernels import ef_sign, ops, ref
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.overlap import build_schedule, exposure_report, reverse_ad_ranks
from repro.overlap.pipeline import build_overlapped_aggregator

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree():
    return {
        "embed": {"table": jnp.arange(40 * 8, dtype=jnp.float32).reshape(40, 8) * 0.01},
        "blocks": [{"w": jnp.linspace(-1, 1, 300, dtype=jnp.float32)}],
        "final_norm": {"g": jnp.ones((50,), jnp.float32)},
        "head": {"w": jnp.linspace(1, -1, 90, dtype=jnp.float32)},
    }


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------


def test_reverse_ad_ranks_stage_order():
    ranks = dict(zip(["blocks", "embed", "final_norm", "head"], reverse_ad_ranks(_tree())))
    assert ranks["final_norm"] == ranks["head"] == 0  # grads first
    assert ranks["blocks"] == 1
    assert ranks["embed"] == 3  # embedding backward last


def test_reverse_ad_ranks_fallback_reversed_flatten():
    tree = {"a": jnp.zeros(3), "m": jnp.zeros(3), "z": jnp.zeros(3)}
    assert reverse_ad_ranks(tree) == (2, 1, 0)


def test_schedule_deterministic_covers_and_balances():
    layout = bucketize.build_layout(_tree(), 64)
    s1 = build_schedule(layout, _tree(), n_groups=3)
    s2 = build_schedule(layout, _tree(), n_groups=3)
    assert s1 == s2, "same layout must give identical groups"
    # exact partition of the bucket set
    seen = set()
    for g in s1.groups:
        for sl in g.slices:
            for b in range(sl.start, sl.stop):
                assert (sl.group, b) not in seen
                seen.add((sl.group, b))
    assert len(seen) == layout.n_buckets
    # issue order follows reverse-AD availability; bytes are balanced
    ranks = [g.rank for g in s1.groups]
    assert ranks == sorted(ranks)
    sizes = [g.wire_bytes for g in s1.groups]
    assert max(sizes) <= 2 * min(sizes)


def test_schedule_clamps_groups_and_rejects_bad_input():
    layout = bucketize.build_layout(_tree(), 64)
    assert build_schedule(layout, _tree(), n_groups=10_000).n_groups <= layout.n_buckets
    assert build_schedule(layout, _tree(), n_groups=1).n_groups == 1
    with pytest.raises(ValueError):
        build_schedule(layout, _tree(), n_groups=0)
    with pytest.raises(ValueError):
        build_schedule(layout, {"wrong": jnp.zeros(3)}, n_groups=2)


# ---------------------------------------------------------------------------
# pipeline latency model
# ---------------------------------------------------------------------------


def test_exposure_report_single_group_is_fully_exposed():
    rep = exposure_report([100.0], [40.0])
    assert rep["exposed_us"] == 40.0 and rep["exposure_frac"] == 1.0


def test_exposure_report_pipelining_hides_comm():
    # 4 equal groups over a long backward: only the tail group's comm exposes
    rep = exposure_report([25.0, 50.0, 75.0, 100.0], [10.0, 10.0, 10.0, 10.0])
    assert rep["serial_comm_us"] == 40.0
    assert rep["exposed_us"] == 10.0  # last group's hop
    assert rep["exposed_us"] < rep["serial_comm_us"]
    # comm-bound wire: hops back up against each other
    rep = exposure_report([1.0, 2.0, 3.0, 4.0], [10.0, 10.0, 10.0, 10.0])
    assert rep["exposed_us"] == pytest.approx(37.0)
    # tail compute hides the last hop too
    rep = exposure_report([25.0, 50.0, 75.0, 100.0], [10.0] * 4, tail_us=10.0)
    assert rep["exposed_us"] == 0.0
    with pytest.raises(ValueError):
        exposure_report([2.0, 1.0], [1.0, 1.0])


# ---------------------------------------------------------------------------
# fused decompress-accumulate kernel (ring hop)
# ---------------------------------------------------------------------------


def test_bucket_sign_accumulate_kernel_matches_ref():
    rng = np.random.default_rng(3)
    acc = jnp.asarray(rng.normal(size=(3, 4096)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(3, 4096)).astype(np.float32))
    words, scales, _, _ = ops.ef_sign_bucket_step(p, jnp.zeros_like(p), force="ref")
    want = ref.bucket_sign_accumulate_ref(acc, words, scales)
    got = ef_sign.bucket_sign_accumulate(acc, words, scales, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # oracle itself: decode + add
    np.testing.assert_allclose(
        np.asarray(want - acc),
        np.asarray(ref.bucket_sign_decode_ref(words, scales)),
        rtol=1e-6,
    )


def test_fused_density_matches_definition():
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32) * 0.1)
    _, _, _, dens = ops.ef_sign_bucket_step(g, e, force="ref")
    np.testing.assert_allclose(np.asarray(dens), np.asarray(jax.vmap(density)(g + e)), rtol=1e-6)
    # all-zero bucket (pure padding): density defined as 1.0
    z = jnp.zeros((1, 128), jnp.float32)
    assert float(ops.ef_sign_bucket_step(z, z, force="ref")[3][0]) == 1.0


# ---------------------------------------------------------------------------
# single-device executor parity (W > 1 runs in subprocesses below)
# ---------------------------------------------------------------------------


def test_overlapped_aggregator_bitwise_single_device():
    mesh = make_host_mesh(data=1, model=1)
    tree = _tree()
    layout = bucketize.build_layout(tree, 64)
    sched = build_schedule(layout, tree, n_groups=3)
    comp = ScaledSignCompressor()
    buckets_w = tuple(b[None] for b in bucketize.flatten_buckets(layout, tree))
    err = tuple(jnp.ones_like(b) * 0.1 for b in buckets_w)
    key = jax.random.PRNGKey(0)
    with use_mesh(mesh):
        spec = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=64)
        one = jax.jit(make_aggregator(spec, layout, mesh, ("data",)))
        ovl = jax.jit(
            build_overlapped_aggregator("ef_allgather", comp, layout, sched, mesh, ("data",))
        )
        o1, o2 = one(buckets_w, err, (), key), ovl(buckets_w, err, (), key)
    for a, b in zip(o1[0] + o1[1], o2[0] + o2[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(o1[3].wire_bytes_per_device) == float(o2[3].wire_bytes_per_device)
    assert float(o1[3].mean_density) == float(o2[3].mean_density)


def test_overlapped_aggregator_rejects_alltoall():
    mesh = make_host_mesh(data=1, model=1)
    layout = bucketize.build_layout(_tree(), 64)
    sched = build_schedule(layout, _tree(), n_groups=2)
    with pytest.raises(ValueError, match="ef_alltoall"):
        build_overlapped_aggregator("ef_alltoall", None, layout, sched, mesh, ("data",))


def test_ef_ring_rejected_on_per_leaf_path():
    from repro.core import aggregation

    with pytest.raises(ValueError, match="bucketed-only"):
        aggregation.init_agg_state("ef_ring", {"x": jnp.zeros(8)}, world=2, bucket_size=None)


def test_overlap_config_from_args():
    from repro.configs.base import DEFAULT_OVERLAP_GROUPS, OverlapConfig

    assert OverlapConfig.from_args(False, None) is None
    assert OverlapConfig.from_args(True, None).n_groups == DEFAULT_OVERLAP_GROUPS
    assert OverlapConfig.from_args(False, 2).n_groups == 2  # implies --overlap
    with pytest.raises(ValueError):
        OverlapConfig.from_args(True, 0)


def test_train_step_rejects_overlap_without_buckets():
    from repro.train import steps as ST

    with pytest.raises(ValueError, match="overlap_groups"):
        ST.make_train_step(
            None,
            None,
            None,
            strategy="dense",
            comp=None,
            local_chain=None,
            ef_axes=(),
            batch_example=None,
            state_example=None,
            bucket_size=None,
            overlap_groups=4,
        )


def test_staged_grad_fn_bitwise_matches_plain():
    from repro.configs import get_config, reduced
    from repro.models import transformer
    from repro.models.act_sharding import activation_sharding
    from repro.train import steps as ST

    cfg = reduced(get_config("llama3_2_1b"))
    assert ST.stageable(cfg, 1)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
    }
    act = lambda: activation_sharding(None, "model")
    (l1, m1), g1 = jax.jit(ST._make_grad_fn(cfg, 1, act))(params, batch)
    (l2, m2), g2 = jax.jit(ST._make_staged_grad_fn(cfg, act))(params, batch)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert jax.tree.structure(g1) == jax.tree.structure(g2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in m1:
        np.testing.assert_array_equal(np.asarray(m1[k]), np.asarray(m2[k]))


# ---------------------------------------------------------------------------
# multi-worker subprocesses
# ---------------------------------------------------------------------------

_RING_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommSpec, bucketize, make_aggregator
from repro.core.compressors import get_compressor
from repro.launch.mesh import make_host_mesh, use_mesh

mesh = make_host_mesh(data=4, model=1)
rng = np.random.default_rng(0)
tree = {"a": jnp.asarray(rng.normal(size=(700,)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(37, 11)).astype(np.float32))}
layout = bucketize.build_layout(tree, 128)
buckets = bucketize.flatten_buckets(layout, tree)
buckets_w = tuple(jnp.asarray(rng.normal(size=(4,) + b.shape).astype(np.float32)) for b in buckets)
err_w = tuple(jnp.asarray(rng.normal(size=b.shape).astype(np.float32) * 0.1) for b in buckets_w)
key = jax.random.PRNGKey(0)
out = {}
with use_mesh(mesh):
    for name, kw in [("scaled_sign", {}), ("sign", {}), ("block_scaled_sign", {}),
                     ("top_k", {"k": 16}), ("random_k", {"k": 16}),
                     ("qsgd", {"s": 7}), ("identity", {})]:
        comp = get_compressor(name, **kw)
        ag = jax.jit(make_aggregator(
            CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=128),
            layout, mesh, ("data",)))
        ring = jax.jit(make_aggregator(
            CommSpec(strategy="ef_ring", compressor=comp, bucket_size=128),
            layout, mesh, ("data",)))
        o1, o2 = ag(buckets_w, err_w, (), key), ring(buckets_w, err_w, (), key)
        # canonical-slot ring: same payloads, same decode → bitwise equal
        agg_equal = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(o1[0], o2[0]))
        err_equal = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(o1[1], o2[1]))
        out[name] = {"agg_equal": agg_equal, "err_equal": err_equal,
                     "wire_equal": float(o1[3].wire_bytes_per_device)
                                   == float(o2[3].wire_bytes_per_device)}
print(json.dumps(out))
"""

_STEP_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST

W = %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=W, model=2) if W > 1 else make_host_mesh(data=1, model=1)
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)

def run(overlap_groups, strategy="ef_allgather"):
    with use_mesh(mesh):
        state = init_train_state(cfg, key, chain, strategy, mesh, ef_axes, bucket_size=4096)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
        bundle = ST.make_train_step(cfg, mesh, rules, strategy=strategy,
            comp=ScaledSignCompressor(), local_chain=chain, ef_axes=ef_axes,
            batch_example=batch, state_example=state, bucket_size=4096,
            overlap_groups=overlap_groups)
        state = jax.device_put(state, bundle.in_shardings[0])
        batch = jax.device_put(batch, bundle.in_shardings[1])
        fn = bundle.jit()
        traj = []
        for _ in range(5):
            state, (loss, m) = fn(state, batch)
            traj.append(float(loss))
        return traj, jax.device_get(jax.tree.leaves(state.params)), float(m["wire_bytes"])

t1, p1, w1 = run(None)
t2, p2, w2 = run(4)
bitwise = (t1 == t2) and all(np.array_equal(a, b) for a, b in zip(p1, p2))
tr, pr, wr = run(None, strategy="ef_ring")
print(json.dumps({"bitwise": bool(bitwise), "wire_equal": w1 == w2,
                  "traj": t1, "ring_traj": tr, "ring_wire": wr, "wire": w1}))
"""


def _run_driver(code_tmpl, **kw):
    code = code_tmpl % {"repo": REPO, **kw}
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_ring_matches_allgather_every_compressor():
    out = _run_driver(_RING_DRIVER)
    for name, r in out.items():
        # same payloads in canonical slots + the same decode-mean → bitwise
        assert r["agg_equal"], f"{name}: ring aggregate must equal allgather"
        assert r["err_equal"], f"{name}: local EF residuals must be identical"
        assert r["wire_equal"], f"{name}: ring must bill allgather's total bytes"


@pytest.mark.slow
@pytest.mark.parametrize("world", [1, 2, 4])
def test_overlapped_step_bitwise_trajectory(world):
    out = _run_driver(_STEP_DRIVER, world=world)
    assert out["bitwise"], f"W={world}: overlapped trajectory diverged: {out['traj']}"
    assert out["wire_equal"]
    # ring strategy trains too, on the same wire bill as allgather
    assert out["ring_traj"][-1] < out["ring_traj"][0], out["ring_traj"]
    assert out["ring_wire"] == out["wire"]
