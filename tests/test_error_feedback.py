"""Error-feedback theory checks: Lemma 3 bound, Theorem IV span distance,
EF-vs-sign convergence behavior on the quadratic."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ScaledSignCompressor,
    TopKCompressor,
    apply_updates,
    ef_step,
    error_norm_sq,
    get_optimizer,
    init_ef_state,
    lemma3_bound,
)


def _quadratic_stream(key, d=64, sigma=1.0, steps=300, gamma=0.05):
    """Noisy gradients of ½‖x‖² with E‖g‖² ≤ σ² bounded; run EF and track ‖e‖²."""
    comp = TopKCompressor(k=4)  # known δ = k/d
    delta = comp.delta(d)
    x = jnp.zeros((d,))
    state = init_ef_state({"x": x})
    max_err, max_g_sq = 0.0, 0.0
    for t in range(steps):
        key, sub = jax.random.split(key)
        g = x + 0.1 * jax.random.normal(sub, (d,))  # bounded since x stays small
        u = {"x": -gamma * g}
        out, state = ef_step(comp, u, state)
        x = x + out["x"]
        max_err = max(max_err, float(error_norm_sq(state)))
        max_g_sq = max(max_g_sq, float(gamma * gamma * jnp.sum(g * g)) / (gamma * gamma))
    return max_err, max_g_sq, delta, gamma


def test_lemma3_error_bound():
    """E‖e_t‖² ≤ 4(1−δ)γ²σ²/δ² — check the trajectory max against the bound
    with the realized σ² (the bound is loose, so this must hold pathwise here)."""
    max_err, sigma_sq, delta, gamma = _quadratic_stream(jax.random.PRNGKey(0))
    bound = lemma3_bound(gamma, sigma_sq, delta)
    assert max_err <= bound, (max_err, bound)


def test_error_zero_when_delta_one():
    from repro.core import IdentityCompressor

    state = init_ef_state({"x": jnp.zeros((16,))})
    out, state = ef_step(IdentityCompressor(), {"x": jnp.ones((16,))}, state)
    assert float(error_norm_sq(state)) == 0.0
    np.testing.assert_allclose(np.asarray(out["x"]), 1.0)


def test_theorem4_span_distance():
    """‖x_t − Π_{G_t} x_t‖ ≤ ‖e_t‖ along a real EF-SIGNSGD run (x₀ = 0)."""
    key = jax.random.PRNGKey(1)
    n, d = 10, 40
    a = jax.random.normal(key, (n, d))
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (n,)))

    def loss(x):
        return jnp.sum((a @ x - y) ** 2)

    comp = ScaledSignCompressor()
    x = jnp.zeros((d,))
    state = init_ef_state({"x": x})
    grads = []
    gamma = 1e-3
    for t in range(200):
        g = jax.grad(loss)(x)
        grads.append(np.asarray(g, np.float64))
        out, state = ef_step(comp, {"x": -gamma * g}, state)
        x = x + out["x"]
        if t % 20 == 0 and t > 0:
            gm = np.stack(grads, axis=1)  # (d, t)
            x64 = np.asarray(x, np.float64)
            proj = gm @ np.linalg.lstsq(gm, x64, rcond=None)[0]
            dist = np.linalg.norm(x64 - proj)
            err = float(jnp.sqrt(error_norm_sq(state)))
            # exact in real arithmetic; float32 grads + lstsq ⇒ small slack
            assert dist <= err * (1 + 1e-3) + 1e-4, (t, dist, err)


def test_ef_signsgd_tracks_sgd_on_ill_conditioned_quadratic():
    """On an ill-conditioned noisy quadratic with a decaying step, EF-SIGNSGD
    converges like SGD (Theorem II rate-matching). The sign-fails/EF-fixes
    separations live in test_counterexamples.py; here every method reaches the
    noise floor, so only the tracking claim is statistically meaningful."""
    from repro.core.optim import step_decay_schedule

    steps = 1200

    def run(name, lr):
        opt = get_optimizer(name, step_decay_schedule(lr, steps))
        p = {"x": jnp.full((8,), 5.0)}
        st = opt.init(p)
        scales = jnp.logspace(-2, 0, 8)

        def loss(q):
            return 0.5 * jnp.sum(scales * q["x"] ** 2)

        key = jax.random.PRNGKey(0)
        for i in range(steps):
            key, sub = jax.random.split(key)
            g = jax.grad(loss)(p)
            g = jax.tree.map(lambda x: x + 0.02 * jax.random.normal(sub, x.shape), g)
            u, st = opt.update(g, st, p)
            p = apply_updates(p, u)
        return float(loss(p))

    f_sgd = run("sgd", 0.5)
    f_ef = run("ef_signsgd", 0.5)
    assert f_ef < 5e-2, f_ef
    assert f_ef < 5 * max(f_sgd, 1e-4), (f_ef, f_sgd)


def test_corrected_density_positive():
    from repro.core import corrected_density

    state = init_ef_state({"w": jnp.zeros((128,))})
    u = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
    out, state = ef_step(ScaledSignCompressor(), u, state)
    dens = corrected_density(u, state)
    assert 0.0 < float(dens["w"]) <= 1.0
