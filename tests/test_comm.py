"""Bucketed comm layer: layout algebra, sign-packing edge cases (including
the ``pack_signs_last``/``unpack_signs_last`` word-boundary cases), per-bucket
EF compression, and the single-device collective path.

These are deterministic (no hypothesis dependency) so the packing edge cases
stay covered even where ``tests/test_compressors.py`` skips.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommSpec, bucketize, compressed, make_aggregator
from repro.core import aggregation
from repro.core import compressors as C
from repro.kernels import ef_sign, ops, ref
from repro.launch.mesh import make_host_mesh, use_mesh

# ---------------------------------------------------------------------------
# sign packing edge cases: n % 32 ∈ {0, 1, 31}, empty leaves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [32, 64, 1, 33, 31, 63, 95])
def test_pack_signs_last_word_boundaries(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(3, n)).astype(np.float32))
    words = C.pack_signs_last(x)
    assert words.shape == (3, C.packed_len(n))
    signs = C.unpack_signs_last(words, n)
    np.testing.assert_array_equal(np.asarray(signs) > 0, np.asarray(x) >= 0)
    # padding bits beyond n are zero — payloads are bit-exact comparable
    if n % 32:
        tail = np.asarray(words)[:, -1]
        assert not np.any(tail >> (n % 32)), "padding bits must be zero"


@pytest.mark.parametrize("n", [0, 1, 31, 32, 33])
def test_pack_signs_flat_word_boundaries(n):
    rng = np.random.default_rng(n + 100)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    words = C.pack_signs(x)
    assert words.shape == (C.packed_len(n),)
    back = C.unpack_signs(words, n)
    assert back.shape == (n,)
    if n:
        np.testing.assert_array_equal(np.asarray(back) > 0, np.asarray(x) >= 0)


def test_pack_signs_last_empty_leaf():
    x = jnp.zeros((4, 0), jnp.float32)
    words = C.pack_signs_last(x)
    assert words.shape == (4, 0)
    assert C.unpack_signs_last(words, 0).shape == (4, 0)


# ---------------------------------------------------------------------------
# bucket layout
# ---------------------------------------------------------------------------


def _tree():
    return {
        "a": jnp.arange(37 * 11, dtype=jnp.float32).reshape(37, 11),
        "b": jnp.arange(5, dtype=jnp.float32).astype(jnp.bfloat16),
        "c": -jnp.arange(301, dtype=jnp.float32),
    }


def test_layout_groups_by_dtype_and_pads():
    layout = bucketize.build_layout(_tree(), 128)
    assert [str(g.dtype) for g in layout.groups] == ["float32", "bfloat16"]
    f32, bf16 = layout.groups
    assert f32.valid == 37 * 11 + 301 and f32.n_buckets == 6  # ceil(708/128)
    assert bf16.valid == 5 and bf16.n_buckets == 1
    assert layout.n_buckets == 7
    assert 0.0 < layout.padding_overhead < 0.25
    # wire accounting is exact per bucket
    assert layout.wire_bits(C.ScaledSignCompressor()) == 7 * (128 + 32)


def test_layout_rejects_non_multiple_of_32():
    with pytest.raises(ValueError):
        bucketize.build_layout(_tree(), 100)


def test_bucket_boundary_split_roundtrip():
    """A leaf larger than bucket_size splits across buckets and reassembles."""
    tree = _tree()
    layout = bucketize.build_layout(tree, 64)  # 'a' (407 elems) spans 7 buckets
    buckets = bucketize.flatten_buckets(layout, tree)
    # element k of 'a' lands at (k // 64, k % 64) of the f32 group stream
    a = np.asarray(tree["a"]).reshape(-1)
    g0 = np.asarray(buckets[0])
    for k in (0, 63, 64, 65, 301, 406):  # straddles every boundary kind
        assert g0[k // 64, k % 64] == a[k]
    # 'c' starts at offset 407 → mid-bucket (boundary split between leaves)
    assert g0[407 // 64, 407 % 64] == np.asarray(tree["c"])[0]
    back = bucketize.unflatten_buckets(layout, buckets)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(back[k], np.float32), np.asarray(tree[k], np.float32), rtol=1e-2
        )
        assert back[k].dtype == tree[k].dtype


def test_layout_empty_leaf():
    tree = {"x": jnp.zeros((0,), jnp.float32), "y": jnp.ones((40,), jnp.float32)}
    layout = bucketize.build_layout(tree, 32)
    buckets = bucketize.flatten_buckets(layout, tree)
    back = bucketize.unflatten_buckets(layout, buckets)
    assert back["x"].shape == (0,)
    np.testing.assert_array_equal(np.asarray(back["y"]), np.asarray(tree["y"]))


def test_valid_mask_covers_padding_only():
    layout = bucketize.build_layout(_tree(), 128)
    mask = np.asarray(bucketize.valid_mask(layout, 0))
    assert mask.sum() == layout.groups[0].valid
    assert mask.reshape(-1)[: layout.groups[0].valid].all()


# ---------------------------------------------------------------------------
# per-bucket EF compression
# ---------------------------------------------------------------------------


def test_ef_encode_sign_matches_per_bucket_sign_encode():
    layout = bucketize.build_layout(_tree(), 128)
    rng = np.random.default_rng(0)
    nb, bs = layout.groups[0].n_buckets, 128
    b = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32) * 0.1)
    mask = bucketize.valid_mask(layout, 0)
    comp = C.ScaledSignCompressor()
    payload, new_err, dens = compressed.ef_encode_buckets(comp, b, e, mask=mask)
    exp = jax.vmap(lambda x: C.sign_encode(x, scaled=True))(b + e)
    np.testing.assert_array_equal(np.asarray(payload.data["words"]), np.asarray(exp.words))
    np.testing.assert_allclose(np.asarray(payload.data["scale"]), np.asarray(exp.scale), rtol=1e-6)
    delta = ref.bucket_sign_decode_ref(payload.data["words"], payload.data["scale"])
    np.testing.assert_allclose(
        np.asarray(new_err), np.asarray((b + e - delta) * mask), rtol=1e-5, atol=1e-6
    )
    assert np.all((np.asarray(dens) > 0) & (np.asarray(dens) <= 1))


def test_ef_encode_generic_compressor_contract():
    """Per-bucket EF with top-k: residual shrinks p by the δ=k/d contract."""
    comp = C.TopKCompressor(k=16)
    rng = np.random.default_rng(1)
    p = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32))
    payload, new_err, _ = compressed.ef_encode_buckets(comp, p, jnp.zeros_like(p))
    dec = compressed.decode_buckets(comp, payload, 128)
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(p - dec), atol=1e-6)
    for row_err, row_p in zip(np.asarray(new_err), np.asarray(p)):
        assert (row_err**2).sum() <= (1 - 16 / 128 + 1e-6) * (row_p**2).sum()


def test_bucket_kernels_interpret_match_ref():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(3, 4096)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(3, 4096)).astype(np.float32) * 0.1)
    w_ref, s_ref, e_ref, d_ref = ops.ef_sign_bucket_step(g, e, force="ref")
    # the fused stats pass reproduces the standalone density definition
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(jax.vmap(C.density)(g + e)), rtol=1e-6)
    l1_pl, l2_pl = ef_sign.bucket_stats(g, e, interpret=True)
    np.testing.assert_allclose(np.asarray(l1_pl), np.asarray(ref.bucket_l1_ref(g, e)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(l2_pl), np.asarray(jnp.sum((g + e) ** 2, axis=-1)), rtol=1e-6
    )
    s_pl = ef_sign.bucket_l1(g, e, interpret=True) / 4096.0
    w_pl, e_pl = ef_sign.bucket_ef_sign_compress(g, e, s_pl, interpret=True)
    np.testing.assert_array_equal(np.asarray(w_pl), np.asarray(w_ref))
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_pl), np.asarray(e_ref), rtol=1e-5, atol=1e-5)
    words = jnp.stack([w_ref, w_ref])
    scales = jnp.stack([s_ref, 2 * s_ref])
    np.testing.assert_allclose(
        np.asarray(ef_sign.bucket_sign_decompress_mean(words, scales, interpret=True)),
        np.asarray(ref.bucket_decompress_mean_ref(words, scales)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# single-device collective path (W=1; multi-worker runs in test_distributed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["dense", "ef_allgather", "ef_alltoall", "majority_vote"])
def test_bucketed_aggregator_single_device(strategy):
    mesh = make_host_mesh(data=1, model=1)
    tree = _tree()
    layout = bucketize.build_layout(tree, 128)
    comp = C.ScaledSignCompressor()
    buckets = bucketize.flatten_buckets(layout, tree)
    buckets_w = tuple(b[None] for b in buckets)
    has_err = strategy.startswith("ef_")
    err = tuple(jnp.zeros_like(b) for b in buckets_w) if has_err else ()
    srv = (
        tuple(s[None] for s in compressed.init_server_buckets(layout, 1))
        if strategy == "ef_alltoall"
        else ()
    )
    with use_mesh(mesh):
        spec = CommSpec(strategy=strategy, compressor=comp, bucket_size=128)
        agg = make_aggregator(spec, layout, mesh, ("data",))
        out, new_err, new_srv, info = jax.jit(agg)(buckets_w, err, srv, jax.random.PRNGKey(0))
    b0, out0 = np.asarray(buckets[0]), np.asarray(out[0])
    mask = np.asarray(bucketize.valid_mask(layout, 0))
    if strategy == "dense":
        np.testing.assert_allclose(out0, b0, rtol=1e-6)
    elif strategy == "majority_vote":
        np.testing.assert_array_equal(out0, np.where(b0 >= 0, 1.0, -1.0) * mask)
    else:
        scales = np.abs(b0).sum(-1) / 128.0
        np.testing.assert_allclose(out0, scales[:, None] * np.where(b0 >= 0, 1.0, -1.0), rtol=1e-5)
    # W=1: every strategy except dense moves zero bytes; dense uses the
    # 2·4·d ring model regardless of world size
    wire = float(info.wire_bytes_per_device)
    if strategy == "dense":
        assert wire == 2 * 4 * layout.padded_elements
    else:
        assert wire == 0.0
    # exact agreement with the analytic bucketed wire models at any W
    assert aggregation.bucketed_sign_allgather_wire_bytes(7, 128, 1) == 0.0
    assert aggregation.bucketed_sign_alltoall_wire_bytes(7, 128, 4) == 2 * 3 * 2 * (128 / 8 + 4)


def test_aggregator_state_roundtrip_init():
    """init_agg_state(bucket_size=...) builds residuals matching the layout."""
    tree = _tree()
    layout = bucketize.build_layout(tree, 128)
    st = aggregation.init_agg_state("ef_alltoall", tree, world=4, bucket_size=128)
    assert len(st.worker_error) == len(layout.groups)
    assert st.worker_error[0].shape == (layout.groups[0].n_buckets, 128)
    nbw = compressed.server_shard_buckets(layout.groups[0].n_buckets, 4)
    assert st.server_error[0].shape == (nbw, 128)
    st2 = aggregation.init_agg_state("majority_vote", tree, bucket_size=128)
    assert st2.worker_error == () and st2.server_error == ()
