import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")
    config.addinivalue_line(
        "markers",
        "tpu: needs a real TPU backend (Pallas compile, not interpret mode); "
        "auto-skipped on CPU/GPU so CI on GitHub-hosted runners stays green",
    )


def pytest_collection_modifyitems(config, items):
    import jax

    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="requires TPU backend (Pallas compile path)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
