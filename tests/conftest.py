import os

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")
    config.addinivalue_line(
        "markers",
        "byz: Byzantine-robust aggregation + fault-injection coverage "
        "(selected as its own CI step so robustness regressions are visible)",
    )
    config.addinivalue_line(
        "markers",
        "fed: federated scenario tier (client sampling, residual-pool "
        "persistence, weighted server combine) — selected as its own CI step "
        "so fed regressions are visible",
    )
    config.addinivalue_line(
        "markers",
        "tpu: needs a real TPU backend (Pallas compile, not interpret mode); "
        "auto-skipped on CPU/GPU so CI on GitHub-hosted runners stays green",
    )
    config.addinivalue_line(
        "markers",
        "pallas: exercises a Pallas kernel or its interpret-mode reference "
        "oracle; runs everywhere (interpret mode works on CPU) and is selected "
        "as its own CI step so kernel regressions are visible — parts that "
        "additionally need hardware carry the tpu marker on top",
    )


def pytest_collection_modifyitems(config, items):
    import jax

    # REPRO_XFAIL_STRICT=1 (set on the latest-jax CI leg) upgrades EVERY
    # xfail marker to strict, overriding per-marker strict=False opt-outs:
    # a version-keyed marker that survives a jax upgrade and starts XPASSing
    # turns the job red instead of passing silently — which is what makes the
    # ROADMAP's "retire the markers when the pin moves" item enforceable.
    force_strict = bool(os.environ.get("REPRO_XFAIL_STRICT"))

    on_tpu = jax.default_backend() == "tpu"
    skip_tpu = pytest.mark.skip(reason="requires TPU backend (Pallas compile path)")
    for item in items:
        if not on_tpu and "tpu" in item.keywords:
            item.add_marker(skip_tpu)
        if force_strict:
            for mark in list(item.iter_markers("xfail")):
                if mark.kwargs.get("strict") is False:
                    kwargs = dict(mark.kwargs, strict=True)
                    # prepended so it is evaluated before the lax original
                    item.add_marker(pytest.mark.xfail(*mark.args, **kwargs), append=False)
