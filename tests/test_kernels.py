"""Pallas kernel validation: interpret-mode vs pure-jnp oracle (ref.py),
swept over shapes, plus semantic equality with the compressors module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import ScaledSignCompressor
from repro.kernels import ops, ref

SIZES = [32, 1000, 1024, 4096, 5 * 1024 + 7, 128 * 1024]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_ef_sign_step_pallas_matches_ref(n, gdtype):
    key = jax.random.PRNGKey(n)
    g = jax.random.normal(key, (n,), gdtype)
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
    gamma = jnp.float32(0.05)

    w_r, s_r, e_r = ops.ef_sign_step(g, e, gamma, force="ref")
    w_p, s_p, e_p = ops.ef_sign_step(g, e, gamma, force="pallas")
    np.testing.assert_array_equal(np.asarray(w_r), np.asarray(w_p))
    np.testing.assert_allclose(float(s_r), float(s_p), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_r), np.asarray(e_p), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [1000, 4096])
def test_ef_sign_step_matches_compressor_semantics(n):
    """kernel == Algorithm 1 lines 4–7 as implemented by ScaledSignCompressor."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    gamma = jnp.float32(0.05)
    w, s, e_new = ops.ef_sign_step(g, e, gamma, force="ref")

    comp = ScaledSignCompressor()
    p = gamma * g + e
    payload = comp.compress(p)
    np.testing.assert_allclose(float(payload.scale), float(s), rtol=1e-5)
    delta = comp.decompress(payload, n)
    np.testing.assert_allclose(np.asarray(p - delta), np.asarray(e_new), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("w", [1, 2, 4, 16])
@pytest.mark.parametrize("rows", [1, 8, 256])
def test_decompress_mean_pallas_matches_ref(w, rows):
    rng = np.random.default_rng(w * 1000 + rows)
    words = jnp.asarray(rng.integers(0, 2**32, size=(w, rows, 32), dtype=np.uint32))
    scales = jnp.asarray(np.abs(rng.normal(size=(w,))).astype(np.float32))
    o_r = ops.decompress_mean(words, scales, force="ref")
    o_p = ops.decompress_mean(words, scales, force="pallas")
    np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_p), rtol=1e-6)


def test_l1_partial_kernel():
    from repro.kernels import ef_sign

    g = jax.random.normal(jax.random.PRNGKey(0), (256, ref.LANE))
    e = jax.random.normal(jax.random.PRNGKey(1), (256, ref.LANE))
    gamma = jnp.float32(0.1)
    out_p = ef_sign.l1_partial(g, e, gamma, interpret=True)
    out_r = ref.l1_partial_ref(g, e, gamma)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=1e-5)


def test_delta_reconstruction():
    n = 1000
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jnp.zeros((n,))
    gamma = jnp.float32(1.0)
    w, s, e_new = ops.ef_sign_step(g, e, gamma, force="ref")
    delta = ops.delta_from(w, s, n, (n,))
    # Δ + e_new == p == γg + e
    np.testing.assert_allclose(np.asarray(delta + e_new), np.asarray(g), rtol=1e-5, atol=1e-6)
