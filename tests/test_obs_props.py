"""Property-based contracts for repro.obs telemetry reducers and wire models.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); the whole
module skips cleanly when it is absent so tier-1 collection never fails — the
deterministic coverage in tests/test_obs.py still runs.
"""

import types

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor
from repro.obs import telemetry as obs_telemetry

ERR_ARRAYS = hnp.arrays(
    np.float32,
    st.tuples(st.integers(1, 6), st.integers(1, 64)),
    # no subnormals: XLA flushes denormals to zero
    elements=st.floats(-1e6, 1e6, width=32, allow_nan=False, allow_subnormal=False),
)


def _layout(n_buckets_per_group, bucket_size):
    """The two attributes the wire models read, without a real param tree."""
    return types.SimpleNamespace(
        bucket_size=bucket_size,
        groups=[types.SimpleNamespace(n_buckets=nb) for nb in n_buckets_per_group],
    )


@hypothesis.given(ERR_ARRAYS)
def test_residual_l2_finite_nonnegative_and_exact(err):
    got = float(obs_telemetry.residual_l2(jnp.asarray(err)))
    assert np.isfinite(got) and got >= 0.0
    np.testing.assert_allclose(got, np.linalg.norm(err.astype(np.float64)), rtol=1e-4)


@hypothesis.given(
    st.lists(st.integers(1, 64), min_size=1, max_size=4),
    st.sampled_from([32, 96, 128, 4096]),
    st.integers(1, 16),
)
def test_wire_models_match_closed_forms(nbs, bucket_size, world):
    layout = _layout(nbs, bucket_size)
    comp = ScaledSignCompressor()
    nb = sum(nbs)
    ag = obs_telemetry.modeled_wire_bytes("ef_allgather", layout, world, comp)
    # the sign family reduces to the closed forms in core.aggregation
    assert ag == aggregation.bucketed_sign_allgather_wire_bytes(nb, bucket_size, world)
    assert obs_telemetry.modeled_wire_bytes("ef_ring", layout, world, comp) == ag
    assert ag == (world - 1) * nb * comp.wire_bits(bucket_size) / 8.0
    mv = obs_telemetry.modeled_wire_bytes("majority_vote", layout, world, comp)
    assert mv == (world - 1) * nb * bucket_size / 8.0
    assert obs_telemetry.modeled_wire_bytes("dense", layout, world, comp) == 8.0 * nb * bucket_size
    # W=1 moves zero compressed bytes under every non-dense strategy
    if world == 1:
        assert ag == mv == 0.0


@hypothesis.given(
    st.lists(st.integers(1, 64), min_size=1, max_size=4),
    st.integers(2, 16),
)
def test_alltoall_model_is_sum_of_per_group_ceils(nbs, world):
    comp = ScaledSignCompressor()
    layout = _layout(nbs, 32)
    got = obs_telemetry.modeled_wire_bytes("ef_alltoall", layout, world, comp)
    expect = sum(
        2 * (world - 1) * (-(-nb // world)) * comp.wire_bits(32) for nb in nbs
    ) / 8.0
    assert got == expect
    # per-group ceils can only round UP relative to one ceil over the total
    total_ceil = 2 * (world - 1) * (-(-sum(nbs) // world)) * comp.wire_bits(32) / 8.0
    assert got >= total_ceil


@hypothesis.given(
    st.lists(st.integers(1, 64), min_size=1, max_size=4),
    st.sampled_from([32, 96, 4096]),
    st.integers(1, 10_000),
)
def test_fed_wire_model_matches_closed_form(nbs, bucket_size, cohort):
    layout = _layout(nbs, bucket_size)
    comp = ScaledSignCompressor()
    got = obs_telemetry.modeled_fed_wire_bytes(layout, cohort, comp)
    # the sign family reduces to the closed form in core.aggregation
    assert got == sum(
        aggregation.fed_round_wire_bytes(nb, bucket_size, cohort) for nb in nbs
    )
    # linear in cohort (only sampled clients pay; no n_clients term at all),
    # and a cohort of W-1 pays exactly the per-device receive bill of a
    # W-worker ef_allgather — the fed tier IS that wire format server-side
    assert got == cohort * obs_telemetry.modeled_fed_wire_bytes(layout, 1, comp)
    assert got == obs_telemetry.modeled_wire_bytes(
        "ef_allgather", layout, cohort + 1, comp
    )


@hypothesis.given(
    st.lists(st.floats(0.0, 1e9, width=32, allow_nan=False), min_size=1, max_size=5)
)
def test_to_host_roundtrips_every_field(group_vals):
    n = len(group_vals)
    t = obs_telemetry.Telemetry(
        err_l2=jnp.asarray(group_vals, jnp.float32),
        density=jnp.linspace(0.0, 1.0, n),
        wire_bytes=jnp.float32(sum(group_vals)),
        group_bytes=jnp.asarray(group_vals, jnp.float32),
        filtered_lanes=jnp.zeros((4,), jnp.float32),
    )
    host = obs_telemetry.to_host(t)
    assert set(host) == {
        "err_l2", "group_density", "group_bytes", "filtered_lanes", "telemetry_wire_bytes",
    }
    assert host["err_l2"] == [float(jnp.float32(v)) for v in group_vals]
    assert all(0.0 <= d <= 1.0 for d in host["group_density"])
    assert host["filtered_lanes"] == [0.0] * 4
    assert host["telemetry_wire_bytes"] == float(jnp.float32(sum(group_vals)))
