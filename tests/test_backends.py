"""Collective-backend registry + CommSpec seam: resolution rules, the error
taxonomy, deprecation shims, the analytic DMA-hop model, and the pallas_dma
kernel's interpret-mode oracles.

Multi-worker parity (spec path vs legacy kwargs, and the ``pallas_dma``
trajectory contract) runs in subprocesses — same isolation pattern as
tests/test_distributed.py — so the main pytest session keeps one CPU device.
"""

import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommSpec,
    PayloadStack,
    backends,
    bucketize,
    collective,
    compressed,
    make_aggregator,
    robust,
)
from repro.comm.errors import (
    BackendCapabilityError,
    CommSpecError,
    PathConfigError,
    ToleranceError,
    UnknownBackendError,
    UnknownStrategyError,
    WireFormatError,
)
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor, get_compressor
from repro.kernels import dma_ring, ref
from repro.launch.mesh import make_host_mesh, use_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree():
    return {"x": jnp.linspace(-1, 1, 300, dtype=jnp.float32)}


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------


def test_registry_names_and_choices():
    assert set(backends.BACKENDS) == {"xla", "ring", "pallas_dma"}
    assert backends.BACKEND_CHOICES == ("auto",) + tuple(backends.BACKENDS)
    for name, be in backends.BACKENDS.items():
        assert be.name == name


def test_lookup_unknown_backend_lists_options():
    with pytest.raises(UnknownBackendError, match="options"):
        backends.lookup("nccl")
    with pytest.raises(UnknownBackendError, match="pallas_dma"):
        backends.lookup("nccl")  # the listing itself names every registered backend


def test_auto_resolution_per_strategy():
    mesh = make_host_mesh(data=1, model=1)
    for strategy, expect in [
        ("ef_ring", "ring"),
        ("ef_allgather", "xla"),  # CPU: no pallas_dma promotion
        ("ef_coord_median", "xla"),
        ("ef_alltoall", "xla"),
        ("dense", "xla"),
    ]:
        spec = CommSpec(strategy=strategy, bucket_size=128)
        assert backends.resolve(spec, mesh, ("data",)).name == expect, strategy


def test_pallas_dma_falls_back_to_ring_off_tpu(caplog):
    if jax.default_backend() == "tpu":
        pytest.skip("fallback path only exists off-TPU")
    mesh = make_host_mesh(data=1, model=1)
    spec = CommSpec(strategy="ef_allgather", bucket_size=128, backend="pallas_dma")
    with caplog.at_level("WARNING"):
        be = backends.resolve(spec, mesh, ("data",))
    assert be.name == "ring"
    assert "falling back" in caplog.text and "pallas_dma" in caplog.text


def test_ring_backend_requires_single_axis():
    mesh = make_host_mesh(data=1, model=1)
    spec = CommSpec(strategy="ef_ring", bucket_size=128)
    with pytest.raises(BackendCapabilityError, match="exactly one EF axis"):
        backends.resolve(spec, mesh, ("data", "model"))


def test_robust_strategies_resolve_on_every_backend():
    """PR 10: robust rides the slot-native exchange — explicit ring (and
    pallas_dma, degrading to ring off-TPU) resolve instead of raising the
    retired robust-needs-xla error; auto keeps the conservative xla."""
    mesh = make_host_mesh(data=1, model=1)
    dma_expect = "pallas_dma" if jax.default_backend() == "tpu" else "ring"
    for strategy in robust.ROBUST_STRATEGIES:
        for backend, expect in [("xla", "xla"), ("ring", "ring"), ("pallas_dma", dma_expect)]:
            spec = CommSpec(strategy=strategy, bucket_size=128, backend=backend)
            assert backends.resolve(spec, mesh, ("data",)).name == expect, (strategy, backend)


def test_mean_only_backend_rejects_robust_strategy():
    """supports_slots is the real capability query that replaced the old
    hard-coded robust×backend special case."""

    class MeanOnly(backends.CollectiveBackend):
        name = "mean_only"
        supports_slots = False

    be = MeanOnly()
    mesh = make_host_mesh(data=1, model=1)
    be.check("ef_allgather", ScaledSignCompressor(), ("data",), mesh)
    with pytest.raises(BackendCapabilityError, match="supports_slots=False"):
        be.check("ef_coord_median", ScaledSignCompressor(), ("data",), mesh)


def test_non_exchange_strategies_stay_xla_only():
    mesh = make_host_mesh(data=1, model=1)
    for strategy in ("dense", "majority_vote", "ef_alltoall"):
        spec = CommSpec(strategy=strategy, bucket_size=128, backend="ring")
        with pytest.raises(BackendCapabilityError, match="xla"):
            backends.resolve(spec, mesh, ("data",))


def test_capability_matrix_cells():
    mesh = make_host_mesh(data=1, model=1)
    mat = backends.capability_matrix(mesh)
    assert set(mat) == set(collective.STRATEGIES)
    for row in mat.values():
        assert set(row) == set(backends.BACKENDS)
    for strategy in robust.ROBUST_STRATEGIES + backends.MEAN_STRATEGIES:
        assert mat[strategy]["xla"] == "ok"
        assert mat[strategy]["ring"] == "ok"
        assert mat[strategy]["pallas_dma"].startswith("ok"), mat[strategy]
    for strategy in ("dense", "majority_vote", "ef_alltoall"):
        assert mat[strategy]["xla"] == "ok"
        assert mat[strategy]["ring"].startswith("--")
        assert mat[strategy]["pallas_dma"].startswith("--")
    # a multi-axis EF world shows up as the rings' single-axis rejection
    mat2 = backends.capability_matrix(mesh, ef_axes=("data", "model"))
    assert mat2["ef_allgather"]["ring"].startswith("--")
    assert mat2["ef_allgather"]["xla"] == "ok"


def test_pallas_dma_backend_speaks_sign_only():
    mesh = make_host_mesh(data=1, model=1)
    be = backends.BACKENDS["pallas_dma"]
    assert be.available() == dma_ring.supported()
    with pytest.raises(BackendCapabilityError, match="sign"):
        be.check("ef_allgather", get_compressor("top_k", k=4), ("data",), mesh)


def test_recommend_backend_consults_latency_model():
    assert backends.recommend_backend(64, 4096, 1) == "xla"
    assert backends.recommend_backend(64, 4096, 2) == "pallas_dma"
    assert backends.recommend_backend(64, 4096, 8) == "pallas_dma"
    assert backends.recommend_backend(64, 4096, 16) == "xla"


# ---------------------------------------------------------------------------
# analytic DMA-hop model
# ---------------------------------------------------------------------------


def test_dma_ring_latency_model_accept_boundary():
    # per-hop launch is amortized against the collective's single launch:
    # accept exactly while (W-1) hop launches cost less than one collective
    # launch (the wire-byte terms are identical on both sides)
    for world in range(2, 12):
        assert aggregation.dma_ring_latency_model(64, 4096, world)["accept"], world
    assert not aggregation.dma_ring_latency_model(64, 4096, 12)["accept"]
    m = aggregation.dma_ring_latency_model(64, 4096, 4)
    assert m["steps"] == 3
    assert m["per_hop_bytes"] == aggregation.bucketed_sign_ring_per_step_bytes(64, 4096)
    assert m["dma_total_us"] == pytest.approx(3 * m["per_hop_us"])


def test_dma_ring_latency_model_degenerate_world_1():
    m = aggregation.dma_ring_latency_model(64, 4096, 1)
    assert m["steps"] == 0 and m["dma_total_us"] == 0.0 and m["accept"]


# ---------------------------------------------------------------------------
# CommSpec validation taxonomy
# ---------------------------------------------------------------------------


def test_spec_unknown_strategy():
    with pytest.raises(UnknownStrategyError, match="unknown bucketed strategy"):
        CommSpec(strategy="ef_warp").validate()


def test_spec_unknown_backend():
    with pytest.raises(UnknownBackendError, match="options"):
        CommSpec(strategy="ef_allgather", bucket_size=128, backend="nccl").validate()


def test_spec_alltoall_wire_format():
    spec = CommSpec(strategy="ef_alltoall", compressor="top_k", bucket_size=128)
    with pytest.raises(WireFormatError, match="sign compressors"):
        spec.validate()


def test_spec_overlap_needs_bucketed_ef_path():
    spec = CommSpec(strategy="dense", overlap=OverlapConfig(n_groups=2))
    with pytest.raises(PathConfigError, match="overlap_groups"):
        spec.validate()


def test_spec_byz_needs_bucketed_ef_path():
    spec = CommSpec(strategy="dense", byz=ByzConfig())
    with pytest.raises(PathConfigError, match="bucketed"):
        spec.validate()


def test_spec_tolerance_is_world_dependent():
    spec = CommSpec(strategy="ef_trimmed_mean", bucket_size=128, byz=ByzConfig(f=1))
    spec.validate()  # structural-only: no world, no breakdown check
    with pytest.raises(ToleranceError, match="0 <= byz_f <= 0"):
        spec.validate(world=2)
    spec.validate(world=4)  # 2f < W: fine
    with pytest.raises(ToleranceError, match="robust"):
        CommSpec(strategy="ef_allgather", bucket_size=128, byz=ByzConfig(f=1)).validate(world=8)


def test_spec_validate_chains_and_errors_are_value_errors():
    spec = CommSpec(strategy="ef_allgather", bucket_size=128)
    assert spec.validate() is spec
    for exc in (
        UnknownStrategyError,
        UnknownBackendError,
        BackendCapabilityError,
        ToleranceError,
        WireFormatError,
        PathConfigError,
    ):
        assert issubclass(exc, CommSpecError) and issubclass(exc, ValueError)


# ---------------------------------------------------------------------------
# deprecation shims (the only sanctioned users of the legacy factories —
# pyproject turns these warnings into errors everywhere else)
# ---------------------------------------------------------------------------


def test_legacy_bucketed_factory_warns_and_matches_spec_path():
    mesh = make_host_mesh(data=1, model=1)
    tree = _tree()
    layout = bucketize.build_layout(tree, 128)
    comp = ScaledSignCompressor()
    buckets_w = tuple(b[None] for b in bucketize.flatten_buckets(layout, tree))
    err = tuple(jnp.ones_like(b) * 0.1 for b in buckets_w)
    key = jax.random.PRNGKey(0)
    with use_mesh(mesh):
        with pytest.warns(DeprecationWarning, match="make_bucketed_aggregator"):
            legacy = collective.make_bucketed_aggregator(
                "ef_allgather", comp, layout, mesh, ("data",)
            )
        spec = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=128)
        spec_path = make_aggregator(spec, layout, mesh, ("data",))
        o1, o2 = legacy(buckets_w, err, (), key), spec_path(buckets_w, err, (), key)
    for a, b in zip(o1[0] + o1[1], o2[0] + o2[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_overlapped_factory_warns():
    from repro.overlap import build_schedule, make_overlapped_aggregator

    mesh = make_host_mesh(data=1, model=1)
    tree = _tree()
    layout = bucketize.build_layout(tree, 64)
    sched = build_schedule(layout, tree, n_groups=2)
    with pytest.warns(DeprecationWarning, match="make_overlapped_aggregator"):
        make_overlapped_aggregator(
            "ef_allgather", ScaledSignCompressor(), layout, sched, mesh, ("data",)
        )


def test_legacy_factory_keeps_canonical_tolerance_error():
    mesh = make_host_mesh(data=1, model=1)
    layout = bucketize.build_layout(_tree(), 128)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ToleranceError, match="byz_f must be >= 0"):
            collective.make_bucketed_aggregator(
                "ef_coord_median", ScaledSignCompressor(), layout, mesh, ("data",), byz_f=-1
            )


# ---------------------------------------------------------------------------
# deprecated backend surface (PR 10 slot-native shims — pyproject errors
# these warnings repo-wide; pytest.warns overrides the filter here)
# ---------------------------------------------------------------------------


class _CannedBackend(backends.CollectiveBackend):
    """Exchange needing no axis context: the payload already carries (W,)."""

    name = "canned"

    def exchange(self, comp, payload, bucket_size, ef_axes, world):
        return PayloadStack(comp, bucket_size, world, slots=payload)


def _gathered_payload(world: int, nb: int = 2, bs: int = 128):
    comp = ScaledSignCompressor()
    rng = np.random.default_rng(world)
    b_w = jnp.asarray(rng.normal(size=(world, nb, bs)).astype(np.float32))
    payload_w, _, _ = jax.vmap(lambda b, e: compressed.ef_encode_buckets(comp, b, e))(
        b_w, jnp.zeros_like(b_w)
    )
    return comp, compressed.BucketPayload(data=payload_w.data)


def test_supports_stack_shim_warns_and_maps_to_supports_slots():
    with pytest.warns(DeprecationWarning, match="supports_stack is deprecated"):
        assert _CannedBackend().supports_stack is True


def test_decode_mean_shim_warns_and_delegates_to_exchange_mean():
    be = _CannedBackend()
    comp, gathered = _gathered_payload(3)
    with pytest.warns(DeprecationWarning, match=r"decode_mean\(\) is deprecated"):
        got = be.decode_mean(comp, gathered, 128, (), 3)
    want = compressed.decode_mean_buckets(comp, gathered, 128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_stack_shim_warns_and_returns_slots():
    be = _CannedBackend()
    _, gathered = _gathered_payload(2)
    with pytest.warns(DeprecationWarning, match=r"gather_stack\(\) is deprecated"):
        out = be.gather_stack(gathered, ())
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(gathered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# PayloadStack view semantics
# ---------------------------------------------------------------------------


def test_payload_stack_needs_exactly_one_slot_source():
    comp, gathered = _gathered_payload(2)
    with pytest.raises(ValueError, match="exactly one"):
        PayloadStack(comp, 128, 2)
    with pytest.raises(ValueError, match="exactly one"):
        PayloadStack(comp, 128, 2, slots=gathered, slots_fn=lambda: gathered)


def test_payload_stack_readings_match_canonical_decodes():
    comp, gathered = _gathered_payload(4)
    view = PayloadStack(comp, 128, 4, slots=gathered)
    assert not view.fused_mean
    np.testing.assert_array_equal(
        np.asarray(view.decoded()),
        np.asarray(compressed.decode_buckets_stack(comp, gathered, 128)),
    )
    np.testing.assert_array_equal(
        np.asarray(view.mean()),
        np.asarray(compressed.decode_mean_buckets(comp, gathered, 128)),
    )


def test_payload_stack_memoizes_and_never_traces_the_unread_reading():
    comp, gathered = _gathered_payload(3)
    calls = {"slots": 0, "mean": 0}

    def slots_fn():
        calls["slots"] += 1
        return gathered

    def mean_fn():
        calls["mean"] += 1
        return compressed.decode_mean_buckets(comp, gathered, 128)

    view = PayloadStack(comp, 128, 3, slots_fn=slots_fn, mean_fn=mean_fn)
    assert view.fused_mean
    view.mean()
    view.mean()
    # the mean-only consumer never pulls the slot gather into the program
    assert calls == {"slots": 0, "mean": 1}
    view.decoded()
    view.decoded()
    view.slots()
    assert calls == {"slots": 1, "mean": 1}


def test_robust_combine_view_collapses_to_mean_at_f0():
    comp, gathered = _gathered_payload(4)
    view = PayloadStack(comp, 128, 4, slots=gathered)
    np.testing.assert_array_equal(
        np.asarray(robust.combine_view("ef_coord_median", view, 0)),
        np.asarray(compressed.decode_mean_buckets(comp, gathered, 128)),
    )
    stack = compressed.decode_buckets_stack(comp, gathered, 128)
    np.testing.assert_array_equal(
        np.asarray(robust.combine_view("ef_trimmed_mean", view, 1)),
        np.asarray(robust.combine_stack("ef_trimmed_mean", stack, 1)),
    )


# ---------------------------------------------------------------------------
# pallas_dma kernel oracles (interpret mode — run everywhere)
# ---------------------------------------------------------------------------


def _payload_stack(world: int, nb: int = 3, bs: int = 128):
    rng = np.random.default_rng(world)
    g = jnp.asarray(rng.normal(size=(world, nb, bs)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(world, nb, bs)).astype(np.float32) * 0.1)
    scales = jax.vmap(ref.bucket_l1_ref)(g, e) / bs
    words, _ = jax.vmap(ref.bucket_ef_sign_compress_ref)(g, e, scales)
    return words, scales


@pytest.mark.pallas
@pytest.mark.parametrize("world", [2, 3, 4, 8])
def test_dma_ring_slots_ref_is_worker_invariant(world):
    """The hop/arrival schedule files every origin: each worker's canonical
    slots equal the plain all-gather stack — the layout the kernel must hit."""
    words, scales = _payload_stack(world)
    for widx in range(world):
        slot_w, slot_s = ref.dma_ring_slots_ref(words, scales, widx)
        np.testing.assert_array_equal(np.asarray(slot_w), np.asarray(words))
        np.testing.assert_array_equal(np.asarray(slot_s), np.asarray(scales))


@pytest.mark.pallas
@pytest.mark.parametrize("world", [2, 5])
def test_dma_ring_mean_ref_equals_allgather_decode(world):
    words, scales = _payload_stack(world)
    want = ref.bucket_decompress_mean_ref(words, scales)
    for widx in range(world):
        got = ref.dma_ring_mean_ref(words, scales, widx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.pallas
def test_seed_slots_kernel_interpret_world_1():
    """The world==1 degenerate of the DMA kernel (slot seeding, no RDMA) in
    interpret mode: pins the slot-store layout against the ref oracle."""
    if dma_ring.pltpu is None:
        pytest.skip("pallas TPU primitives unavailable in this jax build")
    words, scales = _payload_stack(1)
    slot_w, slot_s = dma_ring.dma_ring_gather_slots(
        jnp.int32(0), words[0], scales[0], world=1, interpret=True
    )
    ref_w, ref_s = ref.dma_ring_slots_ref(words, scales, 0)
    np.testing.assert_array_equal(np.asarray(slot_w), np.asarray(ref_w))
    np.testing.assert_array_equal(np.asarray(slot_s), np.asarray(ref_s))


@pytest.mark.pallas
def test_dma_ring_slot_stack_interpret_world_1():
    """The backend's slot reading of the DMA kernel (dma_ring_slot_stack) at
    the world==1 degenerate, under a manual mesh so the in-kernel origin-id
    derivation (lax.axis_index) has its axis: matches the slots-ref oracle."""
    if dma_ring.pltpu is None:
        pytest.skip("pallas TPU primitives unavailable in this jax build")
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import shard_map

    words, scales = _payload_stack(1)
    mesh = make_host_mesh(data=1, model=1)

    def body(w, s):
        slot_w, slot_s = dma_ring.dma_ring_slot_stack(w[0], s[0], ("data",), 1, interpret=True)
        return slot_w[None], slot_s[None]

    out_w, out_s = shard_map(
        body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data"))
    )(words, scales)
    ref_w, ref_s = ref.dma_ring_slots_ref(words, scales, 0)
    np.testing.assert_array_equal(np.asarray(out_w[0]), np.asarray(ref_w))
    np.testing.assert_array_equal(np.asarray(out_s[0]), np.asarray(ref_s))


@pytest.mark.pallas
@pytest.mark.tpu
def test_dma_ring_kernel_compiles_on_tpu():
    """Hardware-only: the multi-device remote-DMA kernel itself (the interpret
    path cannot model cross-chip RDMA). The trajectory contract below pins the
    numerics via the ring fallback everywhere else."""
    from repro.kernels import ops

    world = jax.device_count()
    if world < 2:
        pytest.skip("needs a multi-chip TPU ring")
    words, scales = _payload_stack(world, nb=4, bs=1024)
    mesh = make_host_mesh(data=world, model=1)
    from repro.utils.compat import shard_map

    def body(w, s):
        widx = jax.lax.axis_index("data")
        slot_w, slot_s = dma_ring.dma_ring_gather_slots(widx, w[0], s[0], world=world)
        return ops.bucket_decompress_mean(slot_w, slot_s)[None]

    from jax.sharding import PartitionSpec as P

    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=P("data"),
    )(words, scales)
    want = ref.bucket_decompress_mean_ref(words, scales)
    for widx in range(world):
        np.testing.assert_array_equal(np.asarray(out[widx]), np.asarray(want))


# ---------------------------------------------------------------------------
# multi-worker subprocesses: spec-vs-legacy parity, pallas_dma trajectory
# ---------------------------------------------------------------------------

_PARITY_DRIVER = r"""
import os, json, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommSpec, bucketize, collective, compressed, make_aggregator
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, use_mesh

W = %(world)d
mesh = make_host_mesh(data=W, model=1)
rng = np.random.default_rng(0)
tree = {"a": jnp.zeros((700,), jnp.float32), "b": jnp.zeros((37, 11), jnp.float32)}
layout = bucketize.build_layout(tree, 128)
buckets = bucketize.flatten_buckets(layout, tree)
buckets_w = tuple(jnp.asarray(rng.normal(size=(W,) + b.shape).astype(np.float32))
                  for b in buckets)
err_w = tuple(jnp.asarray(rng.normal(size=b.shape).astype(np.float32) * 0.1)
              for b in buckets_w)
key = jax.random.PRNGKey(0)
comp = ScaledSignCompressor()
out = {}
with use_mesh(mesh):
    for strategy in collective.STRATEGIES:
        has_err = strategy.startswith("ef_")
        err = err_w if has_err else ()
        srv = (tuple(jnp.stack([s] * W) for s in compressed.init_server_buckets(layout, W))
               if strategy == "ef_alltoall" else ())
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = jax.jit(collective.make_bucketed_aggregator(
                strategy, comp, layout, mesh, ("data",)))
        spec = CommSpec(strategy=strategy, compressor=comp, bucket_size=128)
        via_spec = jax.jit(make_aggregator(spec, layout, mesh, ("data",)))
        o1, o2 = legacy(buckets_w, err, srv, key), via_spec(buckets_w, err, srv, key)
        eq = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(jax.tree.leaves(o1[:3]), jax.tree.leaves(o2[:3])))
        wire_eq = float(o1[3].wire_bytes_per_device) == float(o2[3].wire_bytes_per_device)
        out[strategy] = {"bitwise": bool(eq), "wire_equal": bool(wire_eq)}
print(json.dumps(out))
"""

_TRAJ_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommSpec
from repro.configs import get_config, reduced
from repro.core import optim
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST

W = %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=W, model=2)
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)

def run(strategy, backend):
    spec = CommSpec(strategy=strategy, compressor="scaled_sign",
                    bucket_size=4096, backend=backend)
    with use_mesh(mesh):
        state = init_train_state(cfg, key, chain, strategy, mesh, ef_axes,
                                 bucket_size=4096)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                              cfg.vocab_size)}
        bundle = ST.make_train_step(cfg, mesh, rules, spec=spec, local_chain=chain,
            ef_axes=ef_axes, batch_example=batch, state_example=state)
        state = jax.device_put(state, bundle.in_shardings[0])
        batch = jax.device_put(batch, bundle.in_shardings[1])
        fn = bundle.jit()
        traj = []
        for _ in range(5):
            state, (loss, m) = fn(state, batch)
            traj.append(float(loss))
        return traj, jax.device_get(jax.tree.leaves(state.params))

t_ag, p_ag = run("ef_allgather", "auto")
t_dma, p_dma = run("ef_allgather", "pallas_dma")
t_ring, p_ring = run("ef_ring", "auto")
def same(pa, pb):
    return all(np.array_equal(a, b) for a, b in zip(pa, pb))
print(json.dumps({
    "dma_vs_allgather": bool(t_ag == t_dma and same(p_ag, p_dma)),
    "dma_vs_ring": bool(t_dma == t_ring and same(p_dma, p_ring)),
    "traj": t_dma,
}))
"""


_ROBUST_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommSpec, bucketize, make_aggregator, robust
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.obs import telemetry as obs_telemetry

W = %(world)d
F = %(byz_f)d
mesh = make_host_mesh(data=W, model=1)
rng = np.random.default_rng(7)
tree = {"a": jnp.zeros((700,), jnp.float32), "b": jnp.zeros((37, 11), jnp.float32)}
layout = bucketize.build_layout(tree, 128)
buckets = bucketize.flatten_buckets(layout, tree)
grads = [tuple(jnp.asarray(rng.normal(size=(W,) + b.shape).astype(np.float32))
               for b in buckets) for _ in range(5)]
comp = ScaledSignCompressor()
key = jax.random.PRNGKey(0)

def run(strategy, backend, f, telemetry="off", overlap=False):
    spec = CommSpec(strategy=strategy, compressor=comp, bucket_size=128,
                    backend=backend, byz=ByzConfig(f=f) if f else None,
                    telemetry=telemetry,
                    overlap=OverlapConfig(n_groups=2) if overlap else None)
    with use_mesh(mesh):
        agg = jax.jit(make_aggregator(spec, layout, mesh, ("data",),
                                      params=tree if overlap else None))
        err = tuple(jnp.zeros_like(b) for b in grads[0])
        outs = info = None
        for g in grads:  # 5-step trajectory: EF residuals feed forward
            outs, err, _, info = agg(g, err, (), key)
        return ([np.asarray(o) for o in outs], [np.asarray(e) for e in err], info)

def same(a, b):
    return all(np.array_equal(x, y) for x, y in zip(a[0] + a[1], b[0] + b[1]))

mean_runs = ({be: {"ef_allgather": run("ef_allgather", be, 0),
                   "ef_ring": run("ef_ring", be, 0)}
              for be in ("xla", "ring", "pallas_dma")} if F == 0 else None)
res = {}
for strategy in robust.ROBUST_STRATEGIES:
    r = {}
    base = run(strategy, "xla", F)
    for backend in ("ring", "pallas_dma"):
        r["parity_" + backend] = bool(same(base, run(strategy, backend, F)))
    if F == 0:
        # declared-honest robust == the plain mean strategy, per backend
        for backend, runs in mean_runs.items():
            mean_s = "ef_ring" if backend == "ring" else "ef_allgather"
            r["mean_collapse_" + backend] = bool(
                same(run(strategy, backend, 0), runs[mean_s]))
    else:
        r["overlap_matches_oneshot"] = bool(
            same(base, run(strategy, "xla", F, overlap=True)))
    wire = float(base[2].wire_bytes_per_device)
    r["wire_matches_model"] = wire == obs_telemetry.modeled_wire_bytes(
        strategy, layout, W, comp)
    r["wire_matches_allgather"] = wire == obs_telemetry.modeled_wire_bytes(
        "ef_allgather", layout, W, comp)
    # telemetry="full" emits per-lane filter weights on every transport
    lanes = {}
    for backend in ("xla", "ring", "pallas_dma"):
        t = run(strategy, backend, F, telemetry="full")[2].telemetry
        lanes[backend] = None if t is None else [
            float(x) for x in np.asarray(t.filtered_lanes)]
    r["lanes_shape_ok"] = all(v is not None and len(v) == W for v in lanes.values())
    r["lanes_agree"] = len({tuple(v) for v in lanes.values()}) == 1
    res[strategy] = r
print(json.dumps(res))
"""


def _run_driver(code_tmpl, **kw):
    code = code_tmpl % {"repo": REPO, **kw}
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("world", [2, 4])
def test_spec_path_bitwise_matches_legacy_kwargs(world):
    out = _run_driver(_PARITY_DRIVER, world=world)
    assert set(out) == set(collective.STRATEGIES)
    for strategy, r in out.items():
        assert r["bitwise"], f"{strategy}: spec path diverged from legacy kwargs"
        assert r["wire_equal"], f"{strategy}: wire accounting diverged"


@pytest.mark.slow
@pytest.mark.pallas
@pytest.mark.parametrize("world", [2, 4])
def test_pallas_dma_trajectory_bitwise(world):
    """backend='pallas_dma' (ring fallback off-TPU, the documented degrade)
    trains bitwise-identically to ef_allgather and ef_ring over 5 steps."""
    out = _run_driver(_TRAJ_DRIVER, world=world)
    assert out["dma_vs_allgather"], f"W={world}: pallas_dma diverged: {out['traj']}"
    assert out["dma_vs_ring"], f"W={world}: ring strategy diverged: {out['traj']}"
    assert out["traj"][-1] < out["traj"][0], out["traj"]


@pytest.mark.slow
@pytest.mark.byz
@pytest.mark.parametrize("world,byz_f", [(2, 0), (4, 0), (4, 1), (8, 1)])
def test_robust_strategies_ride_every_backend(world, byz_f):
    """The PR-10 acceptance contract: every robust strategy's 5-step EF
    trajectory is bitwise-equal across xla / ring / pallas_dma (off-TPU
    degrade), byz_f=0 collapses bitwise to the backend's own mean strategy
    (W=2 is f=0-only — 2f < W), robust-under-overlap matches one-shot, the
    wire bill equals the analytic model (== allgather's), and telemetry's
    filtered-lane weights come out identical on every transport."""
    out = _run_driver(_ROBUST_DRIVER, world=world, byz_f=byz_f)
    assert set(out) == set(robust.ROBUST_STRATEGIES)
    for strategy, r in out.items():
        ctx = (strategy, world, byz_f)
        assert r["parity_ring"], ctx
        assert r["parity_pallas_dma"], ctx
        assert r["wire_matches_model"], ctx
        assert r["wire_matches_allgather"], ctx
        assert r["lanes_shape_ok"] and r["lanes_agree"], (ctx, r)
        if byz_f == 0:
            for backend in ("xla", "ring", "pallas_dma"):
                assert r[f"mean_collapse_{backend}"], (ctx, backend)
        else:
            assert r["overlap_matches_oneshot"], ctx
