"""Federated tier (repro.fed): spec validation incl. the zero-cohort edge,
deterministic sampling and non-IID shards, the weighted server combine,
residual-pool persistence pinned bitwise across skipped rounds, staleness
mixing, wire accounting against the analytic fed model, loop dispatch through
TrainJob, and (slow) a subprocess proof that a participation=1.0 uniform fed
round is bitwise-equal to the ``ef_allgather`` data-parallel step at
W ∈ {2, 4}.
"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommSpec, bucketize, compressed
from repro.comm.errors import FedConfigError, PathConfigError
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core import aggregation, optim
from repro.core.compressors import ScaledSignCompressor, TopKCompressor
from repro.fed import (
    FedSpec,
    client_sizes,
    dataset_weights,
    init_fed_state,
    make_client_data_fn,
    make_fed_round,
    sample_cohort,
    staleness_weights,
)
from repro.fed import sampling as fed_sampling
from repro.fed import server as fed_server
from repro.fed import shards as fed_shards
from repro.obs import sink as obs_sink
from repro.obs import telemetry as obs_telemetry

pytestmark = pytest.mark.fed

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# FedSpec validation (construction-time error taxonomy)
# ---------------------------------------------------------------------------


def test_fedspec_defaults_and_resolution():
    spec = FedSpec()
    assert spec.cohort_size == spec.n_clients == 100
    assert spec.full_participation
    assert FedSpec(n_clients=10, cohort=3).cohort_size == 3
    assert FedSpec(n_clients=10, participation=0.25).cohort_size == 2
    assert not FedSpec(n_clients=10, cohort=3).full_participation
    assert FedSpec(n_clients=10, participation=1.0).full_participation


def test_fedspec_rejects_zero_cohort():
    # the zero-sampled-cohort edge: both spellings raise at CONSTRUCTION,
    # not as a NaN'd weighted mean at runtime
    with pytest.raises(FedConfigError, match="sample 0 clients"):
        FedSpec(n_clients=10, cohort=0)
    with pytest.raises(FedConfigError, match="rounds to 0"):
        FedSpec(n_clients=10, participation=0.05)


def test_fedspec_rejects_bad_knobs():
    with pytest.raises(FedConfigError, match="n_clients"):
        FedSpec(n_clients=0)
    with pytest.raises(FedConfigError, match="not both"):
        FedSpec(n_clients=10, cohort=3, participation=0.5)
    with pytest.raises(FedConfigError, match="exceeds n_clients"):
        FedSpec(n_clients=4, cohort=9)
    with pytest.raises(FedConfigError, match=r"participation must be in \(0, 1\]"):
        FedSpec(participation=1.5)
    with pytest.raises(FedConfigError, match="unknown fed weighting"):
        FedSpec(weighting="loss")
    with pytest.raises(FedConfigError, match="label_skew"):
        FedSpec(label_skew=-0.1)
    with pytest.raises(FedConfigError, match="size_skew"):
        FedSpec(size_skew=-1.0)
    with pytest.raises(FedConfigError, match="staleness"):
        FedSpec(staleness=-1)
    with pytest.raises(FedConfigError, match="base_examples"):
        FedSpec(base_examples=0)
    # FedConfigError sits in the CommSpecError taxonomy (a ValueError)
    assert issubclass(FedConfigError, ValueError)


def test_fedspec_from_args_factory():
    assert FedSpec.from_args(None, None, None, None, None, None) is None
    spec = FedSpec.from_args(50, None, 0.1, 0.5, 1.0, 2)
    assert spec.n_clients == 50 and spec.cohort_size == 5
    assert spec.label_skew == 0.5 and spec.size_skew == 1.0 and spec.staleness == 2
    # any single flag switches the tier on
    assert FedSpec.from_args(None, None, None, 0.3, None, None).n_clients == 100
    # the zero-cohort edge hits the SAME check through the factory
    with pytest.raises(FedConfigError, match="sample 0 clients"):
        FedSpec.from_args(10, 0, None, None, None, None)


def test_launcher_flags_hit_spec_validation(monkeypatch):
    # the CLI path: bad --cohort / --participation must die at spec
    # validation with the taxonomy error, before any compile
    from repro.launch import train as launch_train

    base = ["prog", "--arch", "llama3.2-1b", "--reduced", "--steps", "1",
            "--strategy", "ef_allgather"]
    monkeypatch.setattr(sys, "argv", base + ["--clients", "10", "--cohort", "0"])
    with pytest.raises(FedConfigError, match="sample 0 clients"):
        launch_train.main()
    monkeypatch.setattr(sys, "argv", base + ["--clients", "10", "--participation", "0.05"])
    with pytest.raises(FedConfigError, match="rounds to 0"):
        launch_train.main()
    # fed needs the bucketed payload-mean path — the rider guard fires too
    monkeypatch.setattr(sys, "argv", base[:-2] + ["--strategy", "dense", "--clients", "4"])
    with pytest.raises(PathConfigError, match="federated tier"):
        launch_train.main()


# ---------------------------------------------------------------------------
# CommSpec fed-rider path guards
# ---------------------------------------------------------------------------


def test_commspec_fed_rider_guards():
    fed = FedSpec(n_clients=4)
    with pytest.raises(PathConfigError, match="federated tier consumes the bucketed"):
        CommSpec(strategy="dense", fed=fed).validate()
    with pytest.raises(PathConfigError, match="federated tier consumes the bucketed"):
        CommSpec(strategy="ef_allgather", bucket_size=None, fed=fed).validate()
    with pytest.raises(PathConfigError, match="payload-mean family"):
        CommSpec(strategy="ef_ring", fed=fed).validate()
    with pytest.raises(PathConfigError, match="byz × fed is not supported"):
        CommSpec(strategy="ef_allgather", fed=fed, byz=ByzConfig(f=1)).validate()
    with pytest.raises(PathConfigError, match="drop the overlap rider"):
        CommSpec(strategy="ef_allgather", fed=fed, overlap=OverlapConfig()).validate()
    spec = CommSpec(strategy="ef_allgather", fed=fed).validate()
    assert spec.fed is fed


# ---------------------------------------------------------------------------
# sampling + weights + shards
# ---------------------------------------------------------------------------


def test_sample_cohort_deterministic_sorted_unique():
    key = jax.random.PRNGKey(3)
    idx = np.asarray(sample_cohort(key, 100, 10))
    again = np.asarray(sample_cohort(key, 100, 10))
    np.testing.assert_array_equal(idx, again)
    assert idx.dtype == np.int32
    assert len(np.unique(idx)) == 10  # without replacement
    np.testing.assert_array_equal(idx, np.sort(idx))
    assert idx.min() >= 0 and idx.max() < 100
    other = np.asarray(sample_cohort(jax.random.PRNGKey(4), 100, 10))
    assert not np.array_equal(idx, other)


def test_dataset_weights_normalized_and_proportional():
    w = np.asarray(dataset_weights(jnp.asarray([10.0, 30.0, 60.0])))
    np.testing.assert_allclose(w, [0.1, 0.3, 0.6], rtol=1e-6)
    assert float(w.sum()) == pytest.approx(1.0)


def test_client_sizes_static_and_skewed():
    flat = client_sizes(16, 0.0, base=32)
    np.testing.assert_array_equal(flat, np.full(16, 32))
    skewed = client_sizes(64, 1.0, seed=0, base=32)
    again = client_sizes(64, 1.0, seed=0, base=32)
    np.testing.assert_array_equal(skewed, again)  # deterministic in (spec, seed)
    assert skewed.min() >= 1
    assert np.mean(skewed) == pytest.approx(32, rel=0.1)
    assert skewed.max() > 2 * skewed.min()  # actually skewed
    assert not np.array_equal(skewed, np.sort(skewed)[::-1])  # shuffled: id != rank


def test_shard_windows_tile_vocab():
    vocab = 256
    assert fed_shards.window_width(vocab, 0.0) == vocab
    assert fed_shards.window_width(vocab, 1.0) == fed_shards.MIN_WINDOW
    width = fed_shards.window_width(vocab, 0.75)
    n = 8
    los = np.asarray(fed_shards.window_lo(jnp.arange(n), n, vocab, width))
    assert los[0] == 0 and los[-1] == vocab - width  # windows span the vocab
    assert (np.diff(los) >= 0).all()
    assert (los + width <= vocab).all()


def test_client_data_fn_windows_and_round_determinism():
    spec = FedSpec(n_clients=8, cohort=2, label_skew=0.75)
    vocab = 256
    width = fed_shards.window_width(vocab, spec.label_skew)
    data_fn = make_client_data_fn(spec, batch=2, seq=16, vocab=vocab)
    key = jax.random.PRNGKey(0)
    idx = jnp.asarray([0, 7], jnp.int32)
    b = jax.device_get(data_fn(idx, key, jnp.int32(0)))
    assert b["tokens"].shape == (2, 2, 16)
    for i, cid in enumerate([0, 7]):
        lo = int(fed_shards.window_lo(jnp.int32(cid), 8, vocab, width))
        assert b["tokens"][i].min() >= lo
        assert b["tokens"][i].max() < lo + width
    # a client's batch depends on (key, round, cid) — NOT on who else was
    # sampled with it
    solo = jax.device_get(data_fn(jnp.asarray([7], jnp.int32), key, jnp.int32(0)))
    np.testing.assert_array_equal(solo["tokens"][0], b["tokens"][1])
    later = jax.device_get(data_fn(idx, key, jnp.int32(1)))
    assert not np.array_equal(later["tokens"], b["tokens"])  # rounds advance data


# ---------------------------------------------------------------------------
# weighted server combine on the unchanged bucket wire format
# ---------------------------------------------------------------------------


def _payload_stack(comp, c, nb, bs, seed=0):
    key = jax.random.PRNGKey(seed)
    buckets_c = jax.random.normal(key, (c, nb, bs))
    err_c = jnp.zeros((c, nb, bs))
    payload_c, _, _ = jax.vmap(
        lambda b, e: compressed.ef_encode_buckets(comp, b, e)
    )(buckets_c, err_c)
    return payload_c


def test_uniform_combine_is_the_dp_decode_bitwise():
    comp = ScaledSignCompressor()
    payload_c = _payload_stack(comp, 4, 3, 32)
    got = fed_server.weighted_combine(comp, payload_c, 32, None)
    want = compressed.decode_mean_buckets(comp, payload_c, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("comp", [ScaledSignCompressor(), TopKCompressor(k=8)],
                         ids=["sign", "topk"])
def test_weighted_combine_matches_numpy_weighted_sum(comp):
    c, nb, bs = 4, 3, 32
    payload_c = _payload_stack(comp, c, nb, bs)
    weights = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    got = np.asarray(fed_server.weighted_combine(comp, payload_c, bs, weights))
    decs = [
        np.asarray(
            compressed.decode_buckets(
                comp,
                compressed.BucketPayload(
                    data=jax.tree.map(lambda x, i=i: x[i], payload_c.data)
                ),
                bs,
            )
        )
        for i in range(c)
    ]
    want = sum(float(w) * d for w, d in zip(np.asarray(weights), decs))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_scatter_rows_touches_only_the_cohort():
    key = jax.random.PRNGKey(1)
    pool = (jax.random.normal(key, (10, 3, 8)),)
    idx = jnp.asarray([2, 5, 9], jnp.int32)
    new = (jnp.ones((3, 3, 8)),)
    out = fed_server.scatter_rows(pool, idx, new)
    gathered = fed_server.gather_rows(out, idx)
    np.testing.assert_array_equal(np.asarray(gathered[0]), np.ones((3, 3, 8)))
    untouched = [i for i in range(10) if i not in (2, 5, 9)]
    np.testing.assert_array_equal(
        np.asarray(out[0][jnp.asarray(untouched)]),
        np.asarray(pool[0][jnp.asarray(untouched)]),
    )


# ---------------------------------------------------------------------------
# the fed round on a toy quadratic: persistence, staleness, wire accounting
# ---------------------------------------------------------------------------

_TOY_N = 40
_TOY_BS = 32


def _toy_problem():
    """d=40 quadratic; per-client optimum encoded by client id, so gradients
    are deterministic in (cid) and the residual-pool pins are exact."""
    params = {"w": jnp.zeros((_TOY_N,), jnp.float32)}
    layout = bucketize.build_layout(params, _TOY_BS)

    def grad_fn(p, b):
        def lf(q):
            r = q["w"] - b["target"]
            return 0.5 * jnp.sum(r * r), {}

        (loss, m), g = jax.value_and_grad(lf, has_aux=True)(p)
        return (loss, m), g

    def data_fn(idx, key, round_idx):
        t = idx.astype(jnp.float32)[:, None] * jnp.linspace(0.5, 1.5, _TOY_N)[None, :]
        return {"target": 0.1 * t}

    return params, layout, grad_fn, data_fn


def _replay_cohorts(spec, seed, rounds):
    """Host-side mirror of the round's RNG: which clients each round sampled."""
    key = jax.random.PRNGKey(seed)
    out = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        out.append(
            np.asarray(
                sample_cohort(
                    jax.random.fold_in(sub, fed_sampling.SAMPLE_TAG),
                    spec.n_clients,
                    spec.cohort_size,
                )
            )
        )
    return out


def test_residual_pool_persists_bitwise_across_skipped_rounds():
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=10, cohort=3)
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()
    rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
    state = init_fed_state(params, chain, layout, spec, seed=0)
    cohorts = _replay_cohorts(spec, 0, 6)
    pool_prev = np.asarray(state.residuals[0])
    for r in range(6):
        state, (loss, metrics) = rf(state)
        pool = np.asarray(state.residuals[0])
        sampled = set(cohorts[r].tolist())
        for cid in range(spec.n_clients):
            row_prev, row = pool_prev[cid], pool[cid]
            if cid in sampled:
                # a sampled client's nonzero gradient leaves a nonzero
                # sign-compression residual
                assert not np.array_equal(row, row_prev) or cid == 0
            else:
                # the paper's partial-participation guarantee: untouched rows
                # are carried BITWISE
                np.testing.assert_array_equal(row, row_prev)
        pool_prev = pool
    # every never-sampled client still holds the zero init
    never = set(range(spec.n_clients)) - set(np.concatenate(cohorts).tolist())
    for cid in never:
        np.testing.assert_array_equal(pool_prev[cid], 0.0)


def test_returning_client_applies_its_carried_residual():
    # a client that skips k rounds re-encodes against the SAME residual row
    # it left behind: its payload equals a fresh encode of (grad-chain
    # update, carried residual) — independent of how many rounds it skipped
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=10, cohort=3)
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()
    rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
    state = init_fed_state(params, chain, layout, spec, seed=0)
    cohorts = _replay_cohorts(spec, 0, 8)
    flat = np.concatenate(cohorts)
    # find a client sampled at least twice with a gap (skip cid 0: its toy
    # optimum is the zero init, so round-0 gradients vanish)
    target, first, second = None, None, None
    for cid in range(1, spec.n_clients):
        rs = [r for r, c in enumerate(cohorts) if cid in c]
        if len(rs) >= 2 and rs[1] - rs[0] > 1:
            target, first, second = cid, rs[0], rs[1]
            break
    assert target is not None, f"no gap-resampled client in {flat}"
    snapshots = {}
    for r in range(second + 1):
        snapshots[r] = np.asarray(state.residuals[0][target])
        state, _ = rf(state)
    # bitwise-unchanged through every skipped round in (first, second)
    after_first = np.asarray(snapshots[first + 1] if first + 1 in snapshots
                             else state.residuals[0][target])
    for r in range(first + 1, second + 1):
        np.testing.assert_array_equal(snapshots[r], after_first)
    # and it DID change at both participations
    assert not np.array_equal(snapshots[first], after_first)
    assert not np.array_equal(
        np.asarray(state.residuals[0][target]), snapshots[second]
    )


def test_staleness_weights_and_first_round_scaling():
    w = staleness_weights(3)
    assert w.shape == (4,)
    assert w.sum() == pytest.approx(1.0)
    assert (np.diff(w) < 0).all()  # older aggregates weigh less
    np.testing.assert_allclose(w, (1 / np.arange(1, 5)) / (1 / np.arange(1, 5)).sum())

    params, layout, grad_fn, data_fn = _toy_problem()
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()
    sync = FedSpec(n_clients=4)
    stale = FedSpec(n_clients=4, staleness=2)
    s0 = init_fed_state(params, chain, layout, sync, seed=0)
    st0 = init_fed_state(params, chain, layout, stale, seed=0)
    assert s0.stale == ()
    assert len(st0.stale) == 1 and st0.stale[0].shape == (2, layout.n_buckets, _TOY_BS)
    s1, _ = jax.jit(make_fed_round(sync, layout, comp, chain, grad_fn, data_fn))(s0)
    t1, _ = jax.jit(make_fed_round(stale, layout, comp, chain, grad_fn, data_fn))(st0)
    # zero history: the async round applies α₀ · fresh — the param delta is
    # the synchronous delta scaled by α₀
    a0 = staleness_weights(2)[0]
    np.testing.assert_allclose(
        np.asarray(t1.params["w"]), a0 * np.asarray(s1.params["w"]), rtol=1e-6
    )
    # the ring buffer now holds the fresh aggregate in slot 0
    assert float(np.abs(np.asarray(t1.stale[0][0])).sum()) > 0.0
    np.testing.assert_array_equal(np.asarray(t1.stale[0][1]), 0.0)


def test_wire_accounting_matches_analytic_models():
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=1000, cohort=5)
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()
    rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
    state = init_fed_state(params, chain, layout, spec, seed=0)
    _, (_, metrics) = rf(state)
    billed = float(metrics["wire_bytes"])
    # only the sampled cohort pays — the bill is independent of n_clients
    assert billed == obs_telemetry.modeled_fed_wire_bytes(layout, 5, comp)
    assert billed == sum(
        aggregation.fed_round_wire_bytes(g.n_buckets, _TOY_BS, 5)
        for g in layout.groups
    )
    bigger = FedSpec(n_clients=10, cohort=5)
    rf2 = jax.jit(make_fed_round(bigger, layout, comp, chain, grad_fn, data_fn))
    st2 = init_fed_state(params, chain, layout, bigger, seed=0)
    _, (_, m2) = rf2(st2)
    assert float(m2["wire_bytes"]) == billed


def test_weighted_round_uses_fedavg_weights():
    # statically non-uniform sizes switch off the uniform fast path; the
    # applied update must differ from the uniform-mean round
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=4)
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()
    sizes = np.asarray([1, 1, 1, 61], dtype=np.int64)
    uni, _ = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))(
        init_fed_state(params, chain, layout, spec, seed=0)
    )
    wtd, _ = jax.jit(
        make_fed_round(spec, layout, comp, chain, grad_fn, data_fn, sizes=sizes)
    )(init_fed_state(params, chain, layout, spec, seed=0))
    assert not np.array_equal(np.asarray(uni.params["w"]), np.asarray(wtd.params["w"]))
    # weighting="uniform" overrides skewed sizes back to the mean path
    uspec = FedSpec(n_clients=4, weighting="uniform")
    u2, _ = jax.jit(
        make_fed_round(uspec, layout, comp, chain, grad_fn, data_fn, sizes=sizes)
    )(init_fed_state(params, chain, layout, uspec, seed=0))
    np.testing.assert_array_equal(np.asarray(uni.params["w"]), np.asarray(u2.params["w"]))
    with pytest.raises(ValueError, match="sizes must have shape"):
        make_fed_round(spec, layout, comp, chain, grad_fn, data_fn,
                       sizes=np.ones(3, dtype=np.int64))
    with pytest.raises(ValueError, match=">= 1"):
        make_fed_round(spec, layout, comp, chain, grad_fn, data_fn,
                       sizes=np.asarray([1, 1, 1, 0], dtype=np.int64))


def test_fed_telemetry_full_is_a_pure_read():
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=10, cohort=4)
    chain = optim.sgd(0.1)
    comp = ScaledSignCompressor()

    def run(telemetry):
        rf = jax.jit(
            make_fed_round(spec, layout, comp, chain, grad_fn, data_fn,
                           telemetry=telemetry)
        )
        state = init_fed_state(params, chain, layout, spec, seed=0)
        traj = []
        for _ in range(4):
            state, (loss, metrics) = rf(state)
            traj.append(float(loss))
        return traj, np.asarray(state.params["w"]), metrics

    t_off, p_off, m_off = run(False)
    t_full, p_full, m_full = run(True)
    assert "obs" not in m_off
    # telemetry is a pure read of intermediates the round already
    # materializes: off/full trajectories are bitwise identical
    assert t_off == t_full
    np.testing.assert_array_equal(p_off, p_full)
    tele = m_full["obs"]
    assert isinstance(tele, obs_telemetry.Telemetry)
    assert float(tele.wire_bytes) == float(m_full["wire_bytes"])
    assert float(np.asarray(tele.group_bytes).sum()) == float(tele.wire_bytes)
    assert tele.filtered_lanes.shape == (4,)  # (cohort,) — no robust filtering
    np.testing.assert_array_equal(np.asarray(tele.filtered_lanes), 0.0)
    assert np.all(np.asarray(tele.density) >= 0.0)
    assert np.all(np.isfinite(np.asarray(tele.err_l2)))


def test_toy_fed_round_converges():
    params, layout, grad_fn, data_fn = _toy_problem()
    spec = FedSpec(n_clients=10, cohort=5)
    chain = optim.sgd(0.1)
    rf = jax.jit(make_fed_round(spec, layout, ScaledSignCompressor(), chain,
                                grad_fn, data_fn))
    state = init_fed_state(params, chain, layout, spec, seed=0)
    losses = []
    for _ in range(20):
        state, (loss, _) = rf(state)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


# ---------------------------------------------------------------------------
# loop dispatch through TrainJob + JSONL records
# ---------------------------------------------------------------------------


def test_run_training_dispatches_to_fed_loop():
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.train.loop import TrainJob, run_training
    from repro.fed.round import FedState

    cfg = reduced(get_config("llama3_2_1b"))
    with tempfile.TemporaryDirectory() as d:
        job = TrainJob(
            cfg=cfg, mesh=make_host_mesh(data=1, model=1), steps=3, batch=2,
            seq=32, lr=0.02, optimizer="sgd", strategy="ef_allgather",
            log_every=1, telemetry="full", log_dir=d,
            fed=FedSpec(n_clients=6, cohort=2, label_skew=0.5, size_skew=1.0),
        )
        state, hist = run_training(job)
        records = obs_sink.read_run(os.path.join(d, "run.jsonl"))
    assert isinstance(state, FedState)
    assert int(state.round) == 3
    assert state.residuals[0].shape[0] == 6  # per-client pool, not per-worker
    kinds = [r["kind"] for r in records]
    assert kinds[0] == "run_meta" and kinds[-1] == "final"
    meta = records[0]
    assert meta["config"]["fed_clients"] == 6 and meta["config"]["fed_cohort"] == 2
    for rec in records[1:-1]:
        # in-graph billed == telemetry read == the analytic fed model
        assert rec["wire_bytes"] == meta["modeled_wire_bytes"]
        assert rec["telemetry_wire_bytes"] == meta["modeled_wire_bytes"]
    assert records[-1]["final_loss"] == pytest.approx(hist[-1]["loss"])


# ---------------------------------------------------------------------------
# bitwise pin: participation=1.0 uniform fed round == ef_allgather DP step
# (subprocess, fake devices; the fed cohort axis sharded over the data axis)
# ---------------------------------------------------------------------------

_FED_PIN_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST
from repro.comm import CommSpec, bucketize
from repro.fed import FedSpec, make_fed_round, init_fed_state
from repro.models.act_sharding import activation_sharding

W = %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=W, model=1)
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)
comp = ScaledSignCompressor()
BS = 4096
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}

with use_mesh(mesh):
    state = init_train_state(cfg, key, chain, "ef_allgather", mesh, ef_axes, bucket_size=BS)
    spec = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=BS)
    bundle = ST.make_train_step(cfg, mesh, rules, spec=spec, local_chain=chain,
                                ef_axes=ef_axes, batch_example=batch, state_example=state)
    state = jax.device_put(state, bundle.in_shardings[0])
    b = jax.device_put(batch, bundle.in_shardings[1])
    fn = bundle.jit()
    traj_dp = []
    for _ in range(5):
        state, (loss, m) = fn(state, b)
        traj_dp.append(float(loss))
    p_dp = jax.device_get(jax.tree.leaves(state.params))
    w_dp = float(m["wire_bytes"])

# fed: W clients == the W EF workers, full participation, uniform sizes
with use_mesh(mesh):
    st0 = init_train_state(cfg, key, chain, "ef_allgather", mesh, ef_axes, bucket_size=BS)
layout = bucketize.build_layout(st0.params, BS)
grad_fn = ST._make_grad_fn(cfg, 1, lambda: activation_sharding(None, "model"))

shard = NamedSharding(mesh, P("data"))
rep = NamedSharding(mesh, P())
wb = jax.tree.map(lambda x: x.reshape(W, x.shape[0] // W, *x.shape[1:]), batch)
wb = jax.device_put(wb, shard)

fspec = FedSpec(n_clients=W)
rf = make_fed_round(fspec, layout, comp, chain, grad_fn, lambda idx, k, r: wb)
fst = init_fed_state(st0.params, chain, layout, fspec, seed=0)
fst = fst._replace(key=st0.agg_state.key)  # same carried key as the DP agg state
state_sh = fst._replace(
    params=jax.tree.map(lambda _: rep, fst.params),
    opt_state=jax.tree.map(lambda _: rep, fst.opt_state),
    residuals=tuple(shard for _ in fst.residuals),
    stale=(),
    key=rep, round=rep,
)
fst = jax.device_put(fst, state_sh)
ffn = jax.jit(rf)
traj_fed = []
with use_mesh(mesh):
    for _ in range(5):
        fst, (loss, m) = ffn(fst)
        traj_fed.append(float(loss))
p_fed = jax.device_get(jax.tree.leaves(fst.params))
w_fed = float(m["wire_bytes"])

bitwise = (traj_dp == traj_fed) and all(np.array_equal(a, c) for a, c in zip(p_dp, p_fed))
print(json.dumps({"W": W, "bitwise": bool(bitwise), "traj_dp": traj_dp,
                  "traj_fed": traj_fed, "wire_dp": w_dp, "wire_fed": w_fed}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("world", [2, 4])
def test_full_participation_round_bitwise_equals_dp_step(world):
    code = _FED_PIN_DRIVER % {"repo": REPO, "world": world}
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # W clients at participation=1.0 with uniform weights ARE the W-worker
    # ef_allgather exchange: same wire format, same decode, same RNG chain —
    # the 5-round trajectory and final params are bitwise identical
    assert out["bitwise"], (
        f"fed round drifted from the DP step: dp={out['traj_dp']} "
        f"fed={out['traj_fed']}"
    )
    # the fed server's bill equals the per-device allgather bill at C == W
    # only for the (W-1)/W receive fraction — assert both are positive and
    # the fed bill is exactly C payload-sets
    assert out["wire_fed"] > 0.0 and out["wire_dp"] > 0.0
