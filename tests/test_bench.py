"""repro.bench subsystem: registry lookup, artifact schema, baseline gate."""

import json

import pytest

from repro.bench import artifact
from repro.bench.artifact import Metric
from repro.bench.cli import main as bench_main
from repro.bench.registry import (
    KNOWN_SUITES,
    BenchContext,
    all_benches,
    benches_for_suite,
    get_bench,
    register_bench,
)


# ---------------------------------------------------------------- registry


def test_registry_has_required_suites_populated():
    for suite in ("kernels", "aggregation", "convergence", "serve", "smoke"):
        assert benches_for_suite(suite), f"suite {suite!r} is empty"


def test_registry_lookup_and_membership():
    b = get_bench("ef_sign_fused_vs_unfused")
    assert "kernels" in b.suites and "smoke" in b.suites
    names = [x.name for x in all_benches()]
    assert names == sorted(names) and len(names) == len(set(names))
    with pytest.raises(KeyError):
        get_bench("no_such_bench")
    with pytest.raises(KeyError):
        benches_for_suite("no_such_suite")


def test_register_rejects_bad_suite_and_duplicates():
    with pytest.raises(ValueError):
        register_bench("x", suites=("not_a_suite",))(lambda ctx: [])
    with pytest.raises(ValueError):
        register_bench("ef_sign_fused_vs_unfused", suites=("kernels",))(lambda ctx: [])


# ---------------------------------------------------------------- artifact schema


def _metrics():
    return [
        Metric(name="t_wall", value=100000.0, metric="wall_time", unit="us",
               direction="lower", tolerance=1.0),
        Metric(name="bytes_moved", value=4096.0, metric="bytes", unit="bytes",
               direction="match", tolerance=0.0),
        Metric(name="speedup", value=2.0, metric="speedup", unit="ratio",
               direction="higher", tolerance=0.25),
    ]


def test_artifact_roundtrip_and_schema(tmp_path):
    path = artifact.write_artifact("smoke", _metrics(), str(tmp_path))
    assert path.endswith("BENCH_smoke.json")
    doc = artifact.load_artifact(path)
    assert artifact.validate_document(doc) == []
    assert doc["schema_version"] == artifact.SCHEMA_VERSION
    assert doc["suite"] == "smoke"
    assert {m["name"] for m in doc["metrics"]} == {"t_wall", "bytes_moved", "speedup"}
    for m in doc["metrics"]:
        for key in ("name", "metric", "unit", "value", "config", "direction", "tolerance"):
            assert key in m


def test_validate_document_flags_problems():
    doc = artifact.to_document("smoke", _metrics())
    doc["metrics"][0]["direction"] = "sideways"
    del doc["metrics"][1]["unit"]
    problems = artifact.validate_document(doc)
    assert any("direction" in p for p in problems)
    assert any("unit" in p for p in problems)


def test_metric_rejects_bad_direction_and_tolerance():
    with pytest.raises(ValueError):
        Metric(name="x", value=1.0, direction="up")
    with pytest.raises(ValueError):
        Metric(name="x", value=1.0, tolerance=-1.0)


# ---------------------------------------------------------------- baseline gate


def _doc(values: dict[str, float]) -> dict:
    base = {m.name: m for m in _metrics()}
    metrics = [
        Metric(name=k, value=v, metric=base[k].metric, unit=base[k].unit,
               direction=base[k].direction, tolerance=base[k].tolerance)
        for k, v in values.items()
    ]
    return artifact.to_document("smoke", metrics)


def test_compare_passes_within_tolerance():
    base = _doc({"t_wall": 100000.0, "bytes_moved": 4096.0, "speedup": 2.0})
    cur = _doc({"t_wall": 150000.0, "bytes_moved": 4096.0, "speedup": 1.8})
    assert artifact.compare(cur, base) == []


def test_compare_flags_injected_regressions():
    base = _doc({"t_wall": 100000.0, "bytes_moved": 4096.0, "speedup": 2.0})
    # wall-clock 3× slower (tol 1.0 + 20 ms abs slack → >2.2× is a regression)
    regs = artifact.compare(_doc({"t_wall": 300000.0, "bytes_moved": 4096.0, "speedup": 2.0}), base)
    assert [r.name for r in regs] == ["t_wall"]
    # deterministic bytes drifted (tol 0 → any change is a regression)
    regs = artifact.compare(_doc({"t_wall": 100000.0, "bytes_moved": 8192.0, "speedup": 2.0}), base)
    assert [r.name for r in regs] == ["bytes_moved"]
    # higher-is-better dropped below slack
    regs = artifact.compare(_doc({"t_wall": 100000.0, "bytes_moved": 4096.0, "speedup": 1.0}), base)
    assert [r.name for r in regs] == ["speedup"]


def test_compare_flags_missing_metric_as_coverage_loss():
    base = _doc({"t_wall": 100000.0, "bytes_moved": 4096.0})
    cur = _doc({"t_wall": 100000.0})
    regs = artifact.compare(cur, base)
    assert [r.name for r in regs] == ["bytes_moved"]
    assert regs[0].current is None


def test_compare_micro_timings_get_absolute_slack():
    """Sub-millisecond wall-clock metrics inform but never gate (ABS_SLACK_US)."""
    base = _doc({"t_wall": 400.0})
    cur = _doc({"t_wall": 4000.0})  # 10x, but within the 20 ms absolute slack
    assert artifact.compare(cur, base) == []


def test_compare_info_and_abs_tolerance():
    """'info' metrics never gate; abs_tolerance loosens zero-valued baselines."""
    info_base = artifact.to_document("smoke", [
        Metric(name="thru", value=500.0, metric="throughput", unit="tok/s", direction="info"),
        Metric(name="ce_f", value=0.0, metric="objective", unit="f",
               direction="match", tolerance=1.0, abs_tolerance=1e-2),
    ])
    cur = artifact.to_document("smoke", [
        Metric(name="thru", value=1.0, metric="throughput", unit="tok/s", direction="info"),
        Metric(name="ce_f", value=0.005, metric="objective", unit="f",
               direction="match", tolerance=1.0, abs_tolerance=1e-2),
    ])
    assert artifact.compare(cur, info_base) == []
    worse = artifact.to_document("smoke", [
        Metric(name="thru", value=1.0, metric="throughput", unit="tok/s", direction="info"),
        Metric(name="ce_f", value=0.5, metric="objective", unit="f",
               direction="match", tolerance=1.0, abs_tolerance=1e-2),
    ])
    assert [r.name for r in artifact.compare(worse, info_base)] == ["ce_f"]


def test_compare_ignores_new_metrics():
    base = _doc({"t_wall": 100000.0})
    cur = _doc({"t_wall": 100000.0, "speedup": 2.0})
    assert artifact.compare(cur, base) == []


# ---------------------------------------------------------------- cli end-to-end


def test_cli_run_gate_roundtrip(tmp_path, monkeypatch):
    """Run one cheap real bench through the CLI, re-gate against its own
    artifact (exit 0), then against a perturbed baseline (exit 1)."""
    out = tmp_path / "a"
    rc = bench_main(["run", "--suite", "kernels", "--only", "ef_sign_hbm_model",
                     "--out", str(out)])
    assert rc == 0
    path = artifact.artifact_path("kernels", str(out))
    doc = artifact.load_artifact(path)
    assert artifact.validate_document(doc) == []

    rc = bench_main(["run", "--suite", "kernels", "--only", "ef_sign_hbm_model",
                     "--out", str(tmp_path / "b"), "--baseline", path])
    assert rc == 0

    doc["metrics"][0]["value"] *= 2  # inject a regression into the baseline
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    rc = bench_main(["run", "--suite", "kernels", "--only", "ef_sign_hbm_model",
                     "--out", str(tmp_path / "c"), "--baseline", str(bad)])
    assert rc == 1


def test_bench_context_fast_flag():
    ctx = BenchContext(suite="smoke", fast=True)
    assert ctx.fast and ctx.suite in KNOWN_SUITES
