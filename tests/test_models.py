"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs — required by the brief for all 10."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import optim
from repro.models import transformer as T


def _batch(cfg, key, bsz=2, seq=32):
    tok_len = seq - cfg.num_patch_tokens if cfg.num_patch_tokens else seq
    batch = {
        "tokens": jax.random.randint(key, (bsz, tok_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (bsz, tok_len), 0, cfg.vocab_size),
    }
    if cfg.num_patch_tokens:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (bsz, cfg.num_patch_tokens, cfg.d_model)
        )
    if cfg.encoder_layers:
        batch["frames"] = 0.1 * jax.random.normal(key, (bsz, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, _, aux = jax.jit(lambda p, b: T.forward(p, cfg, b))(params, batch)
    bsz = batch["tokens"].shape[0]
    seq = batch["tokens"].shape[1] + (cfg.num_patch_tokens or 0)
    assert logits.shape == (bsz, seq, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"

    # one EF-SIGNSGD train step (the paper's optimizer) must reduce nothing to NaN
    opt = optim.ef_sgd(0.01)
    st = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, m), g = jax.value_and_grad(lambda q: T.loss_fn(q, cfg, b), has_aux=True)(p)
        u, s = opt.update(g, s, p)
        return optim.apply_updates(p, u), s, loss

    p2, st, loss = step(params, st, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bsz = 2
    cache = T.init_cache(cfg, bsz, max_len=48, dtype=jnp.float32,
                         with_memory=bool(cfg.encoder_layers))
    if cfg.encoder_layers:
        frames = 0.1 * jax.random.normal(key, (bsz, cfg.encoder_seq, cfg.d_model))
        cache["memory"] = T.encode(params, cfg, frames)
    tok = jnp.ones((bsz, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos)
    )(params, cache, tok, jnp.int32(0))
    assert logits.shape == (bsz, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_nameplate():
    targets = {
        "granite_moe_1b_a400m": (1.33, 0.43),
        "llama3_2_1b": (1.24, 1.24),
        "qwen1_5_4b": (3.95, 3.95),
        "llava_next_mistral_7b": (7.24, 7.24),
        "falcon_mamba_7b": (7.27, 7.27),
        "mistral_nemo_12b": (12.25, 12.25),
        "deepseek_7b": (6.91, 6.91),
        "jamba_1_5_large_398b": (398.6, 94.2),
        "phi3_5_moe_42b_a6_6b": (41.9, 6.64),
        "whisper_large_v3": (1.60, 1.60),
    }
    for arch, (et, ea) in targets.items():
        t, a = get_config(arch).param_counts()
        assert abs(t / 1e9 - et) / et < 0.02, (arch, t / 1e9, et)
        assert abs(a / 1e9 - ea) / ea < 0.02, (arch, a / 1e9, ea)


def test_moe_capacity_drops_and_aux_losses():
    from repro.models import moe as M

    cfg = reduced(get_config("phi3_5_moe_42b_a6_6b"))
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    out, aux = M.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux["moe_aux_loss"]) > 0.5  # ≈1 at balance
    assert np.isfinite(float(aux["moe_z_loss"]))


def test_mamba_scan_matches_sequential():
    """Chunked associative scan == naive per-step recurrence."""
    from repro.models import mamba as M

    cfg = reduced(get_config("falcon_mamba_7b"))
    key = jax.random.PRNGKey(0)
    b, s, di, st_ = 2, 37, cfg.d_inner, cfg.ssm_state
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, di)))
    a = jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (di, st_)) * 0.1)
    b_t = jax.random.normal(jax.random.PRNGKey(2), (b, s, st_))
    c_t = jax.random.normal(jax.random.PRNGKey(3), (b, s, st_))
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, di))
    h0 = jnp.zeros((b, di, st_))

    y_chunk, h_chunk = M.ssm_scan(dt, a, b_t, c_t, x, h0, chunk=8)

    h = h0
    ys = []
    for t in range(s):
        a_bar = jnp.exp(dt[:, t, :, None] * (-a)[None])
        bx = dt[:, t, :, None] * b_t[:, t, None, :] * x[:, t, :, None]
        h = a_bar * h + bx
        ys.append(jnp.einsum("bds,bs->bd", h, c_t[:, t]))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h), rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_dense():
    from repro.models import layers as L

    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, dh = 2, 33, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, dh))

    out = L.chunked_attention(q, k, v, causal=True, chunk=8)

    # dense reference
    import math
    g = hq // hkv
    qh = q.reshape(b, s, hkv, g, dh) / math.sqrt(dh)
    scores = jnp.einsum("bqhgd,bkhd->bqhgk", qh, k)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    ref = jnp.einsum("bqhgk,bkhd->bqhgd", jax.nn.softmax(scores, -1), v).reshape(b, s, hq, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    # sliding window agreement
    out_w = L.chunked_attention(q, k, v, causal=True, window=7, chunk=8)
    maskw = mask & (jnp.arange(s)[None, :] > jnp.arange(s)[:, None] - 7)
    scores_w = jnp.where(maskw[:, None, None, :], jnp.einsum("bqhgd,bkhd->bqhgk", qh, k), -1e30)
    ref_w = jnp.einsum("bqhgk,bkhd->bqhgd", jax.nn.softmax(scores_w, -1), v).reshape(b, s, hq, dh)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref_w), rtol=2e-4, atol=2e-4)
