"""Property-based contracts for the robust estimators (repro.comm.robust)
and the slot-native exchange view (repro.comm.exchange.PayloadStack).

``hypothesis`` is an optional dev dependency (requirements-dev.txt); the whole
module skips cleanly when it is absent so tier-1 collection never fails — the
deterministic oracles in tests/test_byzantine.py still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.comm import PayloadStack, compressed, robust
from repro.core.compressors import ScaledSignCompressor, get_compressor

pytestmark = pytest.mark.byz

STACKS = st.integers(min_value=3, max_value=9).flatmap(
    lambda w: hnp.arrays(
        np.float32,
        st.tuples(st.just(w), st.integers(1, 4), st.integers(1, 16)),
        # no subnormals: XLA flushes denormals to zero
        elements=st.floats(-1e3, 1e3, width=32, allow_nan=False, allow_subnormal=False),
    )
)


@hypothesis.given(STACKS, st.randoms(use_true_random=False))
def test_estimators_permutation_invariant(stack, rng):
    w = stack.shape[0]
    perm = list(range(w))
    rng.shuffle(perm)
    shuffled = stack[perm]
    f = robust.max_tolerance(w)
    for name, fn in (
        ("coord_median", robust.coord_median),
        ("trimmed_mean", lambda s: robust.trimmed_mean(s, f)),
    ):
        a = np.asarray(fn(jnp.asarray(stack)))
        b = np.asarray(fn(jnp.asarray(shuffled)))
        np.testing.assert_array_equal(a, b, err_msg=name)


@hypothesis.given(STACKS)
def test_trimmed_mean_f0_agrees_with_mean(stack):
    # allclose, NOT bitwise: the sorted reduction reassociates the sum
    got = np.asarray(robust.trimmed_mean(jnp.asarray(stack), 0))
    np.testing.assert_allclose(got, stack.mean(axis=0), rtol=1e-4, atol=1e-3)


@hypothesis.given(
    STACKS,
    hnp.arrays(
        np.float32,
        st.just(()),
        elements=st.floats(-1e6, 1e6, width=32, allow_nan=False, allow_subnormal=False),
    ),
)
def test_estimates_bounded_by_honest_range_under_one_adversary(stack, evil):
    """With one arbitrary adversarial row and f=1, both estimators stay
    inside [min, max] of the honest rows per coordinate (2f < W holds for
    every generated W >= 3)."""
    adversarial = np.concatenate([stack, np.full((1,) + stack.shape[1:], evil)])
    lo, hi = stack.min(axis=0), stack.max(axis=0)
    for fn in (
        robust.coord_median,
        lambda s: robust.trimmed_mean(s, 1),
    ):
        est = np.asarray(fn(jnp.asarray(adversarial)))
        assert np.all(est >= lo - 1e-4) and np.all(est <= hi + 1e-4)


# ---------------------------------------------------------------------------
# PayloadStack: the slot-native exchange view every backend returns
# ---------------------------------------------------------------------------

#: every registered compressor the bucketed EF path speaks — the mean-collapse
#: contract is compressor-agnostic, not a sign-family accident
COMPRESSORS = (
    ("scaled_sign", {}),
    ("sign", {}),
    ("block_scaled_sign", {}),
    ("top_k", {"k": 8}),
    ("random_k", {"k": 8}),
    ("qsgd", {}),
    ("low_rank", {}),
    ("identity", {}),
)

# (W, nb, 32) worker bucket stacks: bs % 32 == 0 for the sign word packing,
# W >= 3 so byz_f=1 respects the 2f < W breakdown bound
BUCKET_STACKS = st.integers(min_value=3, max_value=6).flatmap(
    lambda w: hnp.arrays(
        np.float32,
        st.tuples(st.just(w), st.integers(1, 2), st.just(32)),
        elements=st.floats(-1e3, 1e3, width=32, allow_nan=False, allow_subnormal=False),
    )
)


def _exchange_view(comp, b_w):
    """Encode each worker's buckets and wrap the gathered stack exactly the
    way a slot transport's ``exchange()`` does."""
    bs = b_w.shape[-1]
    pays = [
        compressed.ef_encode_buckets(
            comp, jnp.asarray(b), jnp.zeros_like(jnp.asarray(b)), key=jax.random.PRNGKey(i)
        )[0]
        for i, b in enumerate(b_w)
    ]
    data = jax.tree.map(lambda *xs: jnp.stack(xs), *[p.data for p in pays])
    gathered = compressed.BucketPayload(data=data)
    return PayloadStack(comp, bs, len(pays), slots=gathered), gathered


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(BUCKET_STACKS, st.randoms(use_true_random=False))
def test_payload_stack_combines_are_slot_permutation_invariant(b_w, rng):
    """Which lane of the exchange a worker's payload landed in must not move
    the coord_median / trimmed_mean estimate — origin-id slot order is a
    transport detail, not an estimator input."""
    view, gathered = _exchange_view(ScaledSignCompressor(), b_w)
    w = b_w.shape[0]
    perm = list(range(w))
    rng.shuffle(perm)
    shuffled = PayloadStack(
        view.comp,
        view.bucket_size,
        w,
        slots=compressed.BucketPayload(
            data=jax.tree.map(lambda x: x[np.asarray(perm)], gathered.data)
        ),
    )
    for strategy in ("ef_coord_median", "ef_trimmed_mean"):
        a = np.asarray(robust.combine_view(strategy, view, 1))
        b = np.asarray(robust.combine_view(strategy, shuffled, 1))
        np.testing.assert_array_equal(a, b, err_msg=strategy)


@pytest.mark.parametrize("name,kw", COMPRESSORS, ids=[c[0] for c in COMPRESSORS])
@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(BUCKET_STACKS)
def test_payload_stack_mean_collapse_bitwise_for_every_compressor(name, kw, b_w):
    """``view.mean()`` and the byz_f=0 robust collapse are bitwise-equal to
    the canonical ``decode_mean_buckets`` over the same gathered stack, for
    every registered compressor — the contract that keeps a declared-honest
    robust run on today's mean path."""
    comp = get_compressor(name, **kw)
    view, gathered = _exchange_view(comp, b_w)
    want = np.asarray(compressed.decode_mean_buckets(comp, gathered, b_w.shape[-1]))
    np.testing.assert_array_equal(np.asarray(view.mean()), want)
    for strategy in robust.ROBUST_STRATEGIES:
        got = np.asarray(robust.combine_view(strategy, _exchange_view(comp, b_w)[0], 0))
        np.testing.assert_array_equal(got, want, err_msg=strategy)
