"""Property-based contracts for the robust estimators (repro.comm.robust).

``hypothesis`` is an optional dev dependency (requirements-dev.txt); the whole
module skips cleanly when it is absent so tier-1 collection never fails — the
deterministic oracles in tests/test_byzantine.py still run.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.comm import robust

pytestmark = pytest.mark.byz

STACKS = st.integers(min_value=3, max_value=9).flatmap(
    lambda w: hnp.arrays(
        np.float32,
        st.tuples(st.just(w), st.integers(1, 4), st.integers(1, 16)),
        # no subnormals: XLA flushes denormals to zero
        elements=st.floats(-1e3, 1e3, width=32, allow_nan=False, allow_subnormal=False),
    )
)


@hypothesis.given(STACKS, st.randoms(use_true_random=False))
def test_estimators_permutation_invariant(stack, rng):
    w = stack.shape[0]
    perm = list(range(w))
    rng.shuffle(perm)
    shuffled = stack[perm]
    f = robust.max_tolerance(w)
    for name, fn in (
        ("coord_median", robust.coord_median),
        ("trimmed_mean", lambda s: robust.trimmed_mean(s, f)),
    ):
        a = np.asarray(fn(jnp.asarray(stack)))
        b = np.asarray(fn(jnp.asarray(shuffled)))
        np.testing.assert_array_equal(a, b, err_msg=name)


@hypothesis.given(STACKS)
def test_trimmed_mean_f0_agrees_with_mean(stack):
    # allclose, NOT bitwise: the sorted reduction reassociates the sum
    got = np.asarray(robust.trimmed_mean(jnp.asarray(stack), 0))
    np.testing.assert_allclose(got, stack.mean(axis=0), rtol=1e-4, atol=1e-3)


@hypothesis.given(
    STACKS,
    hnp.arrays(
        np.float32,
        st.just(()),
        elements=st.floats(-1e6, 1e6, width=32, allow_nan=False, allow_subnormal=False),
    ),
)
def test_estimates_bounded_by_honest_range_under_one_adversary(stack, evil):
    """With one arbitrary adversarial row and f=1, both estimators stay
    inside [min, max] of the honest rows per coordinate (2f < W holds for
    every generated W >= 3)."""
    adversarial = np.concatenate([stack, np.full((1,) + stack.shape[1:], evil)])
    lo, hi = stack.min(axis=0), stack.max(axis=0)
    for fn in (
        robust.coord_median,
        lambda s: robust.trimmed_mean(s, 1),
    ):
        est = np.asarray(fn(jnp.asarray(adversarial)))
        assert np.all(est >= lo - 1e-4) and np.all(est <= hi + 1e-4)
