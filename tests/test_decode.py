"""Serving-path invariant: prefill + stepwise decode ≡ full forward, for every
architecture family (MoE capacity set high so no token drops — drops are a
legitimate length-dependent semantic, tested separately)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import transformer as T

FP32 = dict(param_dtype="float32", compute_dtype="float32")


def _cfg(arch):
    cfg = reduced(get_config(arch))
    return dataclasses.replace(cfg, capacity_factor=8.0, **FP32)


ARCHS = [
    "llama3_2_1b",  # dense GQA + rope
    "qwen1_5_4b",  # MHA + qkv bias
    "falcon_mamba_7b",  # pure SSM
    "jamba_1_5_large_398b",  # hybrid + moe
    "phi3_5_moe_42b_a6_6b",  # moe
]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bsz, seq = 2, 24
    batch = {"tokens": jax.random.randint(key, (bsz, seq), 0, cfg.vocab_size)}
    logits_full, _, _ = T.forward(params, cfg, batch)

    split = seq - 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :split]
    cache = T.init_cache(cfg, bsz, max_len=seq + 8, dtype=jnp.float32)
    lp, cache, _ = T.forward(params, cfg, pre, cache=cache, pos=0)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - logits_full[:, split - 1])))]
    pos = split
    for i in range(4):
        lg, cache = T.decode_step(
            params, cfg, cache, batch["tokens"][:, pos : pos + 1], jnp.int32(pos)
        )
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, pos]))))
        pos += 1
    assert max(errs) < 2e-2, (arch, errs)


def test_vlm_decode_matches_forward():
    cfg = _cfg("llava_next_mistral_7b")
    cfg = dataclasses.replace(cfg, sliding_window=0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bsz, text = 2, 20
    np_tok = cfg.num_patch_tokens
    batch = {
        "tokens": jax.random.randint(key, (bsz, text), 0, cfg.vocab_size),
        "patch_embeds": 0.1 * jax.random.normal(key, (bsz, np_tok, cfg.d_model)),
    }
    logits_full, _, _ = T.forward(params, cfg, batch)  # (B, np+text, V)

    split = text - 3
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :split]
    cache = T.init_cache(cfg, bsz, max_len=np_tok + text + 8, dtype=jnp.float32)
    lp, cache, _ = T.forward(params, cfg, pre, cache=cache, pos=0)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - logits_full[:, np_tok + split - 1])))]
    pos = np_tok + split
    for i in range(3):
        tok = batch["tokens"][:, split + i : split + i + 1]
        lg, cache = T.decode_step(params, cfg, cache, tok, jnp.int32(pos))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, pos]))))
        pos += 1
    assert max(errs) < 2e-2, errs


def test_encdec_decode_matches_forward():
    cfg = _cfg("whisper_large_v3")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bsz, seq = 2, 20
    frames = 0.1 * jax.random.normal(key, (bsz, cfg.encoder_seq, cfg.d_model))
    batch = {"tokens": jax.random.randint(key, (bsz, seq), 0, cfg.vocab_size), "frames": frames}
    logits_full, _, _ = T.forward(params, cfg, batch)

    split = seq - 3
    cache = T.init_cache(cfg, bsz, max_len=seq + 8, dtype=jnp.float32, with_memory=True)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :split]
    lp, cache, _ = T.forward(params, cfg, pre, cache=cache, pos=0)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - logits_full[:, split - 1])))]
    pos = split
    for i in range(3):
        lg, cache = T.decode_step(
            params, cfg, cache, batch["tokens"][:, pos : pos + 1], jnp.int32(pos)
        )
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, pos]))))
        pos += 1
    assert max(errs) < 2e-2, errs


def test_sliding_window_ring_cache_decode():
    """Decode through a ring-buffer window cache == windowed full forward,
    checked past the wrap-around point."""
    cfg = dataclasses.replace(
        reduced(get_config("llama3_2_1b")), sliding_window=8, **FP32
    )
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bsz, seq = 2, 28
    batch = {"tokens": jax.random.randint(key, (bsz, seq), 0, cfg.vocab_size)}
    logits_full, _, _ = T.forward(params, cfg, batch)  # windowed chunked attention

    split = 6  # well before the window fills; decode far past wrap-around
    cache = T.init_cache(cfg, bsz, max_len=seq + 8, dtype=jnp.float32)
    assert cache["blocks"][0]["k"].shape[2] == 8  # ring buffer is window-sized
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :split]
    _, cache, _ = T.forward(params, cfg, pre, cache=cache, pos=0)
    errs = []
    for pos in range(split, seq):
        lg, cache = T.decode_step(
            params, cfg, cache, batch["tokens"][:, pos : pos + 1], jnp.int32(pos)
        )
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, pos]))))
    assert max(errs) < 2e-2, errs


def test_decode_engine_greedy_deterministic():
    from repro.launch.mesh import make_host_mesh
    from repro.serve.engine import DecodeEngine, ServeConfig

    cfg = _cfg("llama3_2_1b")
    mesh = make_host_mesh(data=1, model=1)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, mesh, params, ServeConfig(max_len=64))
    prompt = {"tokens": jnp.ones((2, 8), jnp.int32)}
    a = eng.generate(prompt, new_tokens=6)
    b = eng.generate(prompt, new_tokens=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert int(jnp.max(a)) < cfg.vocab_size
