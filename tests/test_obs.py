"""Observability layer (repro.obs): the Telemetry pytree and its wire models,
trace spans surviving into compiled HLO, the schema-versioned JSONL sink and
report CLI, loop integration through TrainJob, and (slow) subprocess proofs
that ``telemetry="full"`` leaves the training trajectory bitwise identical to
``"off"`` at W ∈ {2, 4} across strategies and collective backends.
"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import CommSpec, bucketize, make_aggregator
from repro.comm import collective as comm_collective
from repro.comm.errors import PathConfigError
from repro.core import aggregation
from repro.core import compressors as C
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.obs import report as obs_report
from repro.obs import sink as obs_sink
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree():
    key = jax.random.PRNGKey(7)
    return {
        "w": jax.random.normal(key, (5, 130)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (40,)),
    }


# ---------------------------------------------------------------------------
# telemetry schema + wire models
# ---------------------------------------------------------------------------


def test_telemetry_schema_matches_pytree():
    fields = obs_telemetry.telemetry_schema()
    assert tuple(f["name"] for f in fields) == obs_telemetry.Telemetry._fields
    for f in fields:
        assert set(f) == {"name", "shape", "unit", "doc"}


def test_replicated_specs_is_all_replicated():
    specs = obs_telemetry.replicated_specs()
    assert isinstance(specs, obs_telemetry.Telemetry)
    assert all(s == P() for s in specs)


def test_residual_l2_matches_numpy_norm():
    x = np.linspace(-3.0, 5.0, 64, dtype=np.float32).reshape(4, 16)
    got = float(obs_telemetry.residual_l2(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.linalg.norm(x), rtol=1e-6)
    assert float(obs_telemetry.residual_l2(jnp.zeros((3, 8), jnp.bfloat16))) == 0.0


def test_modeled_wire_bytes_matches_closed_forms():
    layout = bucketize.build_layout(_tree(), 128)
    nb, bs = layout.n_buckets, layout.bucket_size
    comp = C.ScaledSignCompressor()
    for world in (1, 2, 4, 16):
        ag = obs_telemetry.modeled_wire_bytes("ef_allgather", layout, world, comp)
        assert ag == aggregation.bucketed_sign_allgather_wire_bytes(nb, bs, world)
        assert obs_telemetry.modeled_wire_bytes("ef_ring", layout, world, comp) == ag
        for robust in ("ef_coord_median", "ef_trimmed_mean", "ef_norm_filter"):
            # the robust strategies decode the same stack: identical wire bill
            assert obs_telemetry.modeled_wire_bytes(robust, layout, world, comp) == ag
        mv = obs_telemetry.modeled_wire_bytes("majority_vote", layout, world, comp)
        assert mv == (world - 1) * nb * bs / 8.0
    assert obs_telemetry.modeled_wire_bytes("dense", layout, 4, comp) == 8.0 * nb * bs


def test_modeled_alltoall_sums_per_group_ceils():
    # two dtype groups: the server shard is ceil-divided per group, so the
    # model must be the SUM of per-group ceils, not the ceil of the sum
    tree = {"a": jnp.zeros((130,), jnp.float32), "b": jnp.zeros((40,), jnp.bfloat16)}
    layout = bucketize.build_layout(tree, 32)
    assert len(layout.groups) == 2
    comp = C.ScaledSignCompressor()
    world = 4
    from repro.comm import compressed

    expect = sum(
        2 * (world - 1) * compressed.server_shard_buckets(g.n_buckets, world) * comp.wire_bits(32)
        for g in layout.groups
    ) / 8.0
    assert obs_telemetry.modeled_wire_bytes("ef_alltoall", layout, world, comp) == expect


def test_strategy_wire_models_covers_every_strategy():
    layout = bucketize.build_layout(_tree(), 128)
    models = obs_telemetry.strategy_wire_models(layout, 4)
    assert set(models) == set(comm_collective.STRATEGIES)
    assert all(v >= 0.0 for v in models.values())
    with pytest.raises(ValueError, match="unknown bucketed strategy"):
        obs_telemetry.modeled_wire_bytes("nope", layout, 4)


# ---------------------------------------------------------------------------
# CommSpec validation
# ---------------------------------------------------------------------------


def test_commspec_rejects_unknown_telemetry_level():
    with pytest.raises(PathConfigError, match="unknown telemetry level"):
        CommSpec(strategy="ef_allgather", telemetry="verbose").validate()


def test_commspec_rejects_telemetry_off_graph_paths():
    # dense never reaches the bucketed aggregator (own GSPMD path) and the
    # per-leaf fallback has no bucketed intermediates to read
    with pytest.raises(PathConfigError, match="telemetry"):
        CommSpec(strategy="dense", telemetry="full").validate()
    with pytest.raises(PathConfigError, match="telemetry"):
        CommSpec(strategy="ef_allgather", bucket_size=None, telemetry="full").validate()


def test_commspec_accepts_bucketed_telemetry():
    for level in obs_telemetry.TELEMETRY_CHOICES:
        CommSpec(strategy="ef_allgather", telemetry=level).validate()


# ---------------------------------------------------------------------------
# aggregator telemetry (W=1 fast path; multi-worker in the slow tests below)
# ---------------------------------------------------------------------------


def _run_w1_aggregator(telemetry):
    mesh = make_host_mesh(data=1, model=1)
    tree = _tree()
    layout = bucketize.build_layout(tree, 128)
    buckets = bucketize.flatten_buckets(layout, tree)
    buckets_w = tuple(b[None] for b in buckets)
    err = tuple(jnp.zeros_like(b) for b in buckets_w)
    with use_mesh(mesh):
        spec = CommSpec(
            strategy="ef_allgather", compressor=C.ScaledSignCompressor(),
            bucket_size=128, telemetry=telemetry,
        )
        agg = make_aggregator(spec, layout, mesh, ("data",))
        jagg = jax.jit(agg)
        out = jagg(buckets_w, err, (), jax.random.PRNGKey(0))
        hlo = jagg.lower(buckets_w, err, (), jax.random.PRNGKey(0)).compile().as_text()
    return layout, out, hlo


def test_aggregator_telemetry_off_is_none():
    _, (_, _, _, info), _ = _run_w1_aggregator("off")
    assert info.telemetry is None


def test_aggregator_telemetry_full_invariants():
    layout, (_, _, _, info), _ = _run_w1_aggregator("full")
    t = info.telemetry
    assert isinstance(t, obs_telemetry.Telemetry)
    n_groups = len(layout.groups)
    assert t.err_l2.shape == (n_groups,)
    assert t.density.shape == (n_groups,)
    dens = np.asarray(t.density)
    assert np.all((dens >= 0.0) & (dens <= 1.0))
    errs = np.asarray(t.err_l2)
    assert np.all(np.isfinite(errs)) and np.all(errs >= 0.0)
    # W=1: nothing crosses the wire, and the split must still sum exactly
    assert float(t.wire_bytes) == obs_telemetry.modeled_wire_bytes("ef_allgather", layout, 1)
    assert float(np.asarray(t.group_bytes).sum()) == float(t.wire_bytes)
    np.testing.assert_array_equal(np.asarray(t.filtered_lanes), np.zeros((1,), np.float32))


def test_spans_survive_into_compiled_hlo():
    # named_scope is metadata-only: it must show up in the COMPILED program's
    # op_name metadata (plain lowered text drops it on jax 0.4.x)
    _, _, hlo = _run_w1_aggregator("off")
    assert obs_trace.SPAN_COMPRESS in hlo
    assert obs_trace.SPAN_DECODE in hlo


def test_span_helpers():
    assert all(n.startswith("obs.") for n in obs_trace.SPAN_NAMES)
    with obs_trace.span("compress"):  # prefixes "obs." when missing
        pass
    with obs_trace.span(obs_trace.SPAN_DECODE):
        pass
    with obs_trace.host_span("host-side"):
        pass
    with obs_trace.step_span(3):
        pass


def test_wall_timers_accumulate_and_drain():
    timers = obs_trace.WallTimers()
    with timers.region("step"):
        pass
    with timers.region("step"):
        pass
    walls = timers.drain()
    assert set(walls) == {"step"} and walls["step"] >= 0.0
    assert timers.drain() == {}


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------


def test_sink_roundtrip():
    meta = obs_sink.run_meta(
        config={"strategy": "ef_allgather", "world": 2},
        telemetry="full",
        modeled_wire_bytes=64.0,
        wire_models={"ef_allgather": 64.0},
    )
    assert meta["telemetry_fields"] == list(obs_telemetry.telemetry_schema())
    step = obs_sink.step_record(
        0,
        {
            "loss": jnp.float32(2.5),
            "wire_bytes": 64.0,
            "density": 0.5,
            "obs": obs_telemetry.Telemetry(
                err_l2=jnp.ones((2,)),
                density=jnp.full((2,), 0.5),
                wire_bytes=jnp.float32(64.0),
                group_bytes=jnp.array([48.0, 16.0]),
                filtered_lanes=jnp.zeros((2,)),
            ),
        },
        walls={"step": 0.25},
    )
    assert step["loss"] == 2.5 and step["wall_step_s"] == 0.25
    assert step["err_l2"] == [1.0, 1.0]
    assert step["group_bytes"] == [48.0, 16.0]
    assert step["telemetry_wire_bytes"] == 64.0
    final = obs_sink.final_record([step], steps=1, wall_s=0.3)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        with obs_sink.RunRecordWriter(path) as wr:
            for rec in (meta, step, final):
                wr.write(rec)
        back = obs_sink.read_run(path)
    assert [r["kind"] for r in back] == ["run_meta", "step", "final"]
    assert back[1] == json.loads(json.dumps(step))


def test_sink_run_meta_off_has_no_field_table():
    assert "telemetry_fields" not in obs_sink.run_meta(config={}, telemetry="off")


def test_final_record_zero_step_run():
    # the launch/train.py epilogue regression: no history must NOT raise
    final = obs_sink.final_record([], steps=0)
    assert final["final_loss"] is None
    assert "last_logged_step" not in final


def test_sink_rejects_unknown_schema():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        with open(path, "w") as fh:
            fh.write(json.dumps({"schema": 999, "kind": "step"}) + "\n")
        with pytest.raises(ValueError, match="schema 999"):
            obs_sink.read_run(path)


def test_sink_writer_closed_raises():
    with tempfile.TemporaryDirectory() as d:
        wr = obs_sink.RunRecordWriter(os.path.join(d, "run.jsonl"))
        wr.close()
        with pytest.raises(ValueError, match="closed"):
            wr.write({"schema": 1})


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------


def _synthetic_records(wire=64.0, modeled=64.0, density=0.5, err=0.01, lanes=None):
    meta = obs_sink.run_meta(
        config={"strategy": "ef_allgather"}, telemetry="full", modeled_wire_bytes=modeled
    )
    steps = []
    for i in range(6):
        rec = {
            "schema": 1, "kind": "step", "step": i, "loss": 2.0 - 0.1 * i,
            "wire_bytes": wire, "density": density, "err_l2": [err],
        }
        if lanes is not None:
            rec["filtered_lanes"] = lanes
        steps.append(rec)
    final = obs_sink.final_record(steps, steps=6)
    return [meta, *steps, final]


def test_report_clean_run():
    summary = obs_report.summarize(_synthetic_records())
    assert summary["anomalies"] == []
    assert summary["final_loss"] == pytest.approx(1.5)
    text = obs_report.format_summary(summary)
    assert "match" in text and "anomalies: none" in text


def test_report_flags_wire_model_mismatch():
    summary = obs_report.summarize(_synthetic_records(wire=60.0, modeled=64.0))
    assert "wire_model_mismatch" in summary["anomalies"]
    assert "MISMATCH" in obs_report.format_summary(summary)


def test_report_flags_density_out_of_unit():
    summary = obs_report.summarize(_synthetic_records(density=1.5))
    assert "density_out_of_unit" in summary["anomalies"]


def test_report_flags_residual_blowup():
    records = _synthetic_records()
    for i, rec in enumerate(r for r in records if r["kind"] == "step"):
        rec["err_l2"] = [0.01 * (100.0 if i >= 3 else 1.0)]
    summary = obs_report.summarize(records)
    assert "residual_blowup" in summary["anomalies"]


def test_report_flags_suspect_lanes():
    summary = obs_report.summarize(_synthetic_records(lanes=[0.0, 3.0, 0.0, 0.5]))
    assert summary["suspect_lanes"] == [1]
    assert "suspect_lanes" in summary["anomalies"]


def test_report_cli_json(capsys):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        with obs_sink.RunRecordWriter(path) as wr:
            for rec in _synthetic_records():
                wr.write(rec)
        assert obs_report.main([path, "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["n_step_records"] == 6 and summary["anomalies"] == []


# ---------------------------------------------------------------------------
# loop integration (W=1; the real launcher path runs in CI's obs smoke step)
# ---------------------------------------------------------------------------


def test_training_loop_writes_schema_valid_records():
    from repro.configs import get_config, reduced
    from repro.train.loop import TrainJob, run_training

    cfg = reduced(get_config("llama3_2_1b"))
    mesh = make_host_mesh(data=1, model=1)
    with tempfile.TemporaryDirectory() as d:
        job = TrainJob(
            cfg=cfg, mesh=mesh, steps=3, batch=2, seq=32, lr=0.02,
            optimizer="sgd", strategy="ef_allgather", log_every=2,
            telemetry="full", log_dir=d,
        )
        _, hist = run_training(job)
        records = obs_sink.read_run(os.path.join(d, "run.jsonl"))
    kinds = [r["kind"] for r in records]
    assert kinds[0] == "run_meta" and kinds[-1] == "final"
    assert kinds.count("step") == len(hist) == 2
    meta = records[0]
    assert meta["telemetry"] == "full" and "modeled_wire_bytes" in meta
    for rec in records[1:-1]:
        assert rec["telemetry_wire_bytes"] == meta["modeled_wire_bytes"]
        assert rec["wire_bytes"] == meta["modeled_wire_bytes"]
        assert len(rec["err_l2"]) == len(rec["group_density"]) >= 1
        assert rec["wall_step_s"] > 0.0
    summary = obs_report.summarize(records)
    assert "wire_model_mismatch" not in summary["anomalies"]
    assert records[-1]["final_loss"] == pytest.approx(hist[-1]["loss"])


# ---------------------------------------------------------------------------
# multi-worker bitwise invariance (subprocess, fake devices)
# ---------------------------------------------------------------------------

_BITWISE_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST
from repro.comm import CommSpec, bucketize
from repro.obs.telemetry import modeled_wire_bytes

W, STRATEGY, BACKEND = %(world)d, %(strategy)r, %(backend)r
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=W, model=1)
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)
comp = ScaledSignCompressor()
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}

with use_mesh(mesh):
    layout = bucketize.build_layout(
        init_train_state(cfg, key, chain, STRATEGY, mesh, ef_axes, bucket_size=4096).params, 4096
    )

def run(level):
    with use_mesh(mesh):
        # fresh (identical) state per run: bundle.jit() donates its input
        state = init_train_state(cfg, key, chain, STRATEGY, mesh, ef_axes, bucket_size=4096)
        spec = CommSpec(strategy=STRATEGY, compressor=comp, bucket_size=4096,
                        backend=BACKEND, telemetry=level)
        bundle = ST.make_train_step(cfg, mesh, rules, spec=spec, local_chain=chain,
                                    ef_axes=ef_axes, batch_example=batch, state_example=state)
        state = jax.device_put(state, bundle.in_shardings[0])
        b = jax.device_put(batch, bundle.in_shardings[1])
        fn = bundle.jit()
        traj = []
        for _ in range(5):
            state, (loss, m) = fn(state, b)
            traj.append(float(loss))
        tele = None
        if "obs" in m:
            t = m["obs"]
            tele = {"wire": float(t.wire_bytes),
                    "density": [float(x) for x in np.asarray(t.density)],
                    "err_l2": [float(x) for x in np.asarray(t.err_l2)],
                    "group_sum": float(np.asarray(t.group_bytes).sum()),
                    "lanes": [float(x) for x in np.asarray(t.filtered_lanes)]}
        return traj, jax.device_get(jax.tree.leaves(state.params)), float(m["wire_bytes"]), tele

t_off, p_off, w_off, none_tele = run("off")
t_full, p_full, w_full, tele = run("full")
bitwise = (t_off == t_full) and all(np.array_equal(a, b) for a, b in zip(p_off, p_full))
print(json.dumps({"bitwise": bool(bitwise), "traj": t_off,
                  "wire_off": w_off, "wire_full": w_full,
                  "modeled": modeled_wire_bytes(STRATEGY, layout, W, comp),
                  "off_has_tele": none_tele is not None, "tele": tele}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("world", [2, 4])
@pytest.mark.parametrize(
    "strategy,backend",
    [("ef_allgather", "auto"), ("ef_ring", "auto"), ("ef_allgather", "pallas_dma")],
)
def test_telemetry_full_vs_off_bitwise(world, strategy, backend):
    code = _BITWISE_DRIVER % {
        "repo": REPO, "world": world, "strategy": strategy, "backend": backend
    }
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # telemetry is a pure read: the 5-step trajectory and final params are
    # bitwise identical with it on or off
    assert out["bitwise"], f"telemetry changed the trajectory: {out['traj']}"
    assert not out["off_has_tele"]
    # the billed wire equals the analytic model EXACTLY, both levels
    assert out["wire_off"] == out["wire_full"] == out["modeled"]
    tele = out["tele"]
    assert tele is not None
    assert tele["wire"] == out["modeled"]
    assert tele["group_sum"] == tele["wire"]
    assert all(0.0 <= d <= 1.0 for d in tele["density"])
    assert all(np.isfinite(e) and e >= 0.0 for e in tele["err_l2"])
    assert len(tele["lanes"]) == world and all(x == 0.0 for x in tele["lanes"])
