"""Multi-device tests, run in subprocesses so the main pytest session keeps a
single CPU device (the brief forbids a global device-count override).

The EF strategies run through the bucketed comm layer (``repro.comm``) by
default — per-worker grads via vmap + fully-manual shard_map collectives —
which works on every supported jax, including jaxlib 0.4.x where the older
per-leaf path's partial-manual shard_map aborts in XLA. The per-leaf
``bucket_size=None`` fallback keeps its own (version-keyed xfail) coverage
below so the latest-jax CI leg still exercises it.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.utils import compat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DRIVER = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(%(repo)r, "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST

strategy, policy, use_pod, bucket = %(strategy)r, %(policy)r, %(pod)r, %(bucket)r
mesh = make_host_mesh(pod=2, data=2, model=2) if use_pod else make_host_mesh(data=4, model=2)
cfg = reduced(get_config("llama3_2_1b"))
key = jax.random.PRNGKey(0)
rules = ShardingRules(cfg, mesh, policy)
ef_axes = (("pod",) if use_pod else ef_axis_names(mesh, policy)) if strategy != "dense" else ()
chain = optim.sgd(0.02)
with use_mesh(mesh):
    state = init_train_state(cfg, key, chain, strategy, mesh, ef_axes, bucket_size=bucket)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
    bundle = ST.make_train_step(cfg, mesh, rules, strategy=strategy,
        comp=ScaledSignCompressor(), local_chain=chain, ef_axes=ef_axes,
        batch_example=batch, state_example=state, bucket_size=bucket)
    state = jax.device_put(state, bundle.in_shardings[0])
    batch = jax.device_put(batch, bundle.in_shardings[1])
    fn = bundle.jit()
    losses = []
    for i in range(6):
        state, (loss, m) = fn(state, batch)
        losses.append(float(loss))
    # params identical across devices (aggregated update consistency)
    leaf = jax.device_get(jax.tree.leaves(state.params)[0])
    print(json.dumps({"losses": losses, "wire": float(m["wire_bytes"]),
                      "density": float(m["density"])}))
"""

# fixed-size comm buckets for the reduced config: small enough that every
# strategy sees a multi-bucket stream (boundary splits, a2a bucket shards)
BUCKET = 4096


def _run(strategy, policy, pod, bucket=BUCKET):
    code = DRIVER % {
        "repo": REPO, "strategy": strategy, "policy": policy, "pod": pod,
        "bucket": bucket if strategy != "dense" else None,
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize(
    "strategy,policy,pod",
    [
        ("dense", "tp", False),
        ("ef_allgather", "tp", False),
        ("ef_alltoall", "tp", False),
        # EF over the pod axis, fsdp inside
        ("ef_allgather", "fsdp", True),
        ("ef_alltoall", "fsdp", True),
    ],
)
def test_train_step_strategies(strategy, policy, pod):
    out = _run(strategy, policy, pod)
    losses = out["losses"]
    assert losses[-1] < losses[0], losses
    if strategy != "dense":
        assert 0.0 < out["density"] <= 1.0
        # compressed exchange must move far fewer bytes than dense fp32
        dense_bytes = 8.0 * 1.0e6  # order-of-magnitude guard
        assert out["wire"] < dense_bytes


@pytest.mark.slow
def test_wire_bytes_ratio_signsgd_vs_dense():
    dense = _run("dense", "tp", False)
    ef = _run("ef_allgather", "tp", False)
    a2a = _run("ef_alltoall", "tp", False)
    # paper's headline: sign compression cuts wire bytes by ~running factor;
    # bucketed all-gather: ~(64/W)×(1 − scale overhead) (W=4 here → ~16×);
    # bucketed all-to-all double compression: ~32×, W-independent
    assert dense["wire"] / ef["wire"] > 10, (dense["wire"], ef["wire"])
    assert dense["wire"] / a2a["wire"] > 20, (dense["wire"], a2a["wire"])


# ---------------------------------------------------------------------------
# per-leaf fallback (bucket_size=None): jaxlib 0.4.x aborts
# (`Check failed: sharding.IsManualSubgroup()`) on the partial-manual
# shard_map this path needs — for the scan over layers and again for the
# manual-axis collectives; fixed in newer XLA. The subprocess dies with
# SIGABRT, so xfail (non-strict) keeps the documented-but-broken combo from
# reddening CI on the pinned jax; the latest-jax CI leg runs it for real.
# ---------------------------------------------------------------------------

_xfail_manual_subgroup = pytest.mark.xfail(
    compat.OLD_JAX,
    reason="XLA IsManualSubgroup abort in partial-manual shard_map on jaxlib "
    "0.4.x (re-probed 2026-08-09 on the 0.4.37 pin: subprocess still dies "
    "SIGABRT with `Check failed: sharding.IsManualSubgroup()` for both "
    "ef_allgather and ef_alltoall — marker stays until the pin moves)",
    strict=False,
)


@pytest.mark.slow
@pytest.mark.parametrize(
    "strategy",
    [
        pytest.param("ef_allgather", marks=_xfail_manual_subgroup),
        pytest.param("ef_alltoall", marks=_xfail_manual_subgroup),
    ],
)
def test_train_step_per_leaf_fallback(strategy):
    out = _run(strategy, "tp", False, bucket=None)
    assert out["losses"][-1] < out["losses"][0], out["losses"]
    assert out["wire"] < 8.0e6
