"""Per-architecture parameter/activation sharding rules.

Policies
--------
``dp``    — params replicated; batch over the data axes.
``tp``    — Megatron-style tensor parallelism over ``model``: attention heads /
            flattened head dims column-parallel, output projections row-
            parallel, experts expert-parallel, vocab sharded.
``fsdp``  — ``tp`` plus the complementary big dim sharded over ``data``
            (ZeRO-3 / GSPMD fully-sharded; per-layer all-gathers inserted by
            the compiler).

Every rule checks divisibility against the mesh axis size and silently drops
an axis that does not divide (e.g. qwen's 20 heads on a 16-way model axis fall
back to feature-dim sharding — see DESIGN.md §5).

Parameters are never sharded over the ``pod`` axis: pods are pure data
parallel, and the inter-pod hop is exactly where the paper's compressed
aggregation (``repro.core.aggregation``) is applied.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def default_policy(cfg: ModelConfig) -> str:
    total, _ = cfg.param_counts()
    return "fsdp" if total > 2e9 else "tp"


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _ok(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


class ShardingRules:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, policy: str | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy or default_policy(cfg)
        self.model_size = _axis(mesh, "model")
        self.data_size = _axis(mesh, "data")
        self.dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    # -------------------------------------------------------------- #
    # leaf-level helpers
    # -------------------------------------------------------------- #

    def _m(self, dim: int):
        return "model" if _ok(dim, self.model_size) else None

    def _d(self, dim: int):
        if self.policy != "fsdp":
            return None
        return "data" if _ok(dim, self.data_size) else None

    def _matmul_spec(self, shape, col_parallel: bool, stacked: bool):
        """(..., d_in, d_out) weight: column-parallel shards d_out over model,
        row-parallel shards d_in over model; fsdp shards the other over data."""
        lead = (None,) if stacked else ()
        d_in, d_out = shape[-2], shape[-1]
        if col_parallel:
            return P(*lead, self._d(d_in), self._m(d_out))
        return P(*lead, self._m(d_in), self._d(d_out))

    # -------------------------------------------------------------- #
    # parameter tree
    # -------------------------------------------------------------- #

    def param_specs(self, params) -> Any:
        if self.policy == "dp":
            return jax.tree.map(lambda _: P(), params)

        def rule(path, leaf):
            names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
            names = [n for n in names if isinstance(n, str)]
            stacked = "blocks" in names  # leading repeats dim
            shape = leaf.shape
            lead = (None,) if stacked else ()
            tail = names[-2:] if len(names) >= 2 else names

            # embeddings / head. NOTE: the table feeds a gather — XLA's SPMD
            # partitioner (0.8.x) hard-crashes partitioning a gather whose
            # *feature* dim is sharded under a manual pod axis, so the table
            # only ever shards dim 0 (vocab), over model (+data for fsdp).
            if "embed" in names:
                if self.policy == "fsdp" and _ok(shape[0], self.model_size * self.data_size):
                    return P(("model", "data"), None)
                return P(self._m(shape[0]), None)
            if "head" in names:
                if leaf.ndim == 1:
                    return P(self._m(shape[0]))
                return self._matmul_spec(shape, col_parallel=True, stacked=False)

            # norms & small vectors
            if leaf.ndim - len(lead) <= 1:
                dim = shape[-1]
                if any(n in names for n in ("conv_b", "dt_bias_init", "D")) or (
                    tail and tail[-1] == "b"
                    and any(x in names for x in ("wq", "wk", "wv", "in", "gate", "in_proj", "dt_proj"))
                ):
                    return P(*lead, self._m(dim))
                return P(*lead) if lead else P()

            # attention projections
            if any(n in names for n in ("wq", "wk", "wv")):
                return self._matmul_spec(shape, col_parallel=True, stacked=stacked)
            if "wo" in names:
                return self._matmul_spec(shape, col_parallel=False, stacked=stacked)

            # MoE
            if "router" in names:
                return P(*lead, None, self._m(shape[-1]))
            if "w_in" in names or "w_gate" in names:  # (R,E,D,F)
                return P(*lead, self._m(shape[len(lead)]), self._d(shape[-2]), None)
            if "w_out" in names:  # (R,E,F,D)
                return P(*lead, self._m(shape[len(lead)]), self._d(shape[-2]), None)

            # mamba
            if "in_proj" in names:
                return self._matmul_spec(shape, col_parallel=True, stacked=stacked)
            if "out_proj" in names:
                return self._matmul_spec(shape, col_parallel=False, stacked=stacked)
            if "conv_w" in names:  # (R,K,di)
                return P(*lead, None, self._m(shape[-1]))
            if "x_proj" in names:  # (R,di,dr+2st)
                return P(*lead, self._m(shape[-2]), None)
            if "dt_proj" in names:  # (R,dr,di)
                return P(*lead, None, self._m(shape[-1]))
            if "A_log" in names:  # (R,di,st)
                return P(*lead, self._m(shape[-2]), None)

            # MLP
            if "in" in names or "gate" in names:
                return self._matmul_spec(shape, col_parallel=True, stacked=stacked)
            if "out" in names:
                return self._matmul_spec(shape, col_parallel=False, stacked=stacked)
            return P(*lead) if lead else P()

        return jax.tree_util.tree_map_with_path(rule, params)

    # -------------------------------------------------------------- #
    # batch / cache / activation specs
    # -------------------------------------------------------------- #

    def batch_specs(self, batch_example) -> Any:
        """Shard the leading batch dim over all dp axes (when divisible)."""
        dp = self.dp_axes
        dp_size = 1
        for a in dp:
            dp_size *= _axis(self.mesh, a)

        def rule(leaf):
            b = leaf.shape[0]
            if _ok(b, dp_size) or b == dp_size:
                return P(dp, *([None] * (leaf.ndim - 1)))
            return P(*([None] * leaf.ndim))

        return jax.tree.map(rule, batch_example)

    def cache_specs(self, cache) -> Any:
        """KV caches: batch over data if divisible, else cache-time over data;
        kv-heads over model if divisible, else head_dim. Mamba state: d_inner
        over model."""
        data = "data" if self.data_size > 1 else None

        def rule(path, leaf):
            names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
            names = [n for n in names if isinstance(n, str)]
            if "memory" in names:  # (B, F, D)
                b = leaf.shape[0]
                bspec = data if _ok(b, self.data_size) else None
                return P(bspec, None, self._m(leaf.shape[-1]))
            if "k" in names or "v" in names:  # (R,B,T,H,Dh)
                _, b, t, h, dh = leaf.shape
                if _ok(b, self.data_size):
                    bspec, tspec = data, None
                else:
                    bspec, tspec = None, (data if _ok(t, self.data_size) else None)
                hspec = self._m(h)
                dspec = self._m(dh) if hspec is None else None
                return P(None, bspec, tspec, hspec, dspec)
            if "conv" in names:  # (R,B,K-1,di)
                b = leaf.shape[1]
                return P(None, data if _ok(b, self.data_size) else None, None, self._m(leaf.shape[-1]))
            if "ssm" in names:  # (R,B,di,st)
                b = leaf.shape[1]
                return P(None, data if _ok(b, self.data_size) else None, self._m(leaf.shape[-2]), None)
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(rule, cache)

    # -------------------------------------------------------------- #

    def named(self, specs) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def opt_specs(self, params):
        """Momentum/Adam state mirrors the param sharding."""
        return self.param_specs(params)
