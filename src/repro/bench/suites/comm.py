"""Comm-layer benches: bucket layout build, per-bucket compress / all-gather
decode hot loops, and exact per-step wire-byte accounting for every bucketed
strategy (cross-checked against the analytic models in core/aggregation.py).

Run ``python -m repro.bench run --suite comm`` for the BENCH_comm.json
artifact; the cheap deterministic subset also rides in ``smoke``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.artifact import Metric
from repro.bench.measure import bytes_metric, time_fn, wall_metric
from repro.bench.registry import register_bench
from repro.comm import api as comm_api
from repro.comm import bucketize, collective, compressed
from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor, get_compressor
from repro.launch.mesh import make_host_mesh, use_mesh

BUCKET_SIZE = 1 << 14  # 16384 elems — many buckets even on reduced configs


def _layout_for(arch: str, bucket_size: int = BUCKET_SIZE):
    from repro.configs import get_config, reduced
    from repro.models import transformer

    cfg = reduced(get_config(arch))
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(cfg, k), jax.random.PRNGKey(0)
    )
    return bucketize.build_layout(shapes, bucket_size)


@register_bench("comm_bucket_layout", suites=("comm", "smoke"))
def comm_bucket_layout(ctx):
    """BucketLayout build cost over real param specs + the static layout
    facts (bucket count, padding overhead) the wire accounting hangs off."""
    from repro.configs import ARCH_IDS

    archs = ("llama3_2_1b",) if ctx.fast else tuple(ARCH_IDS)[:4]
    metrics = []
    for arch in archs:
        t = time_fn(lambda a=arch: _layout_for(a), iters=3 if ctx.fast else 10, warmup=1)
        layout = _layout_for(arch)
        cfg_d = {"arch": arch, "bucket_size": BUCKET_SIZE}
        metrics.append(wall_metric(f"comm_layout_build_{arch}", t, config=cfg_d))
        metrics.append(
            Metric(
                name=f"comm_layout_{arch}_n_buckets", value=float(layout.n_buckets),
                metric="layout", unit="buckets", config=cfg_d,
                direction="match", tolerance=0.0,
            )
        )
        metrics.append(
            Metric(
                name=f"comm_layout_{arch}_padding_overhead",
                value=round(layout.padding_overhead, 6),
                metric="layout", unit="fraction", config=cfg_d,
                # padding waste is pure overhead: growing it is a regression
                direction="lower", tolerance=0.05,
            )
        )
    return metrics


@register_bench("comm_bucket_compress", suites=("comm", "smoke"))
def comm_bucket_compress(ctx):
    """Per-bucket EF sign compress + W-payload decode-mean hot loops, plus the
    exact per-bucket wire cost of each compressor family."""
    nb, bs = (8, BUCKET_SIZE) if ctx.fast else (32, BUCKET_SIZE)
    rng_g, rng_e = jax.random.split(jax.random.PRNGKey(ctx.seed))
    g = jax.random.normal(rng_g, (nb, bs))
    e = jax.random.normal(rng_e, (nb, bs)) * 0.1
    comp = ScaledSignCompressor()
    iters = 5 if ctx.fast else 20
    cfg_d = {"n_buckets": nb, "bucket_size": bs}
    metrics = []

    encode = jax.jit(lambda g, e: compressed.ef_encode_buckets(comp, g, e))
    t = time_fn(encode, g, e, iters=iters)
    metrics.append(wall_metric("comm_ef_encode_buckets", t, config=cfg_d))

    payload, _, _ = encode(g, e)
    for w in (4,) if ctx.fast else (4, 16):
        gathered = compressed.BucketPayload(
            data=jax.tree.map(lambda x: jnp.stack([x] * w), payload.data)
        )
        dec = jax.jit(lambda p: compressed.decode_mean_buckets(comp, p, bs))
        t = time_fn(dec, gathered, iters=iters)
        metrics.append(
            wall_metric(f"comm_decode_mean_w{w}", t, config=dict(cfg_d, w=w))
        )

    # per-bucket wire bytes: the schema-pinned accounting unit of the layer
    for name, c in (
        ("sign", comp),
        ("top_k", get_compressor("top_k", k=64)),
        ("qsgd4bit", get_compressor("qsgd", s=7)),
        ("dense", get_compressor("identity")),
    ):
        metrics.append(
            bytes_metric(
                f"comm_wire_bytes_per_bucket_{name}",
                c.wire_bits(bs) / 8.0,
                config={"bucket_size": bs, "compressor": name},
            )
        )
    return metrics


@register_bench("comm_step_wire_accounting", suites=("comm", "smoke"))
def comm_step_wire_accounting(ctx):
    """End-to-end bucketed aggregate per strategy on the host mesh: wall
    clock, emitted AggInfo wire bytes/density, and the analytic bucketed wire
    models at production world sizes (the deterministic gate)."""
    mesh = make_host_mesh(data=1, model=1)
    layout = _layout_for("llama3_2_1b")
    comp = ScaledSignCompressor()
    nb, bs = layout.n_buckets, layout.bucket_size
    key = jax.random.PRNGKey(ctx.seed)
    buckets = tuple(
        jax.random.normal(jax.random.fold_in(key, gi), (1, g.n_buckets, bs))
        for gi, g in enumerate(layout.groups)
    )
    iters = 3 if ctx.fast else 10
    metrics = []
    with use_mesh(mesh):
        for strategy in collective.STRATEGIES:
            has_err = strategy.startswith("ef_")
            err = tuple(jnp.zeros_like(b) for b in buckets) if has_err else ()
            srv = (
                tuple(s[None] for s in compressed.init_server_buckets(layout, 1))
                if strategy == "ef_alltoall"
                else ()
            )
            spec = comm_api.CommSpec(strategy=strategy, compressor=comp, bucket_size=bs)
            agg = comm_api.make_aggregator(spec, layout, mesh, ("data",))
            fn = jax.jit(lambda b, e, s, k, _agg=agg: _agg(b, e, s, k))
            out = fn(buckets, err, srv, key)
            jax.block_until_ready(out)
            info = out[3]
            cfg_d = {"strategy": strategy, "n_buckets": nb, "bucket_size": bs, "world": 1}
            metrics.append(
                bytes_metric(
                    f"comm_{strategy}_wire_bytes",
                    float(info.wire_bytes_per_device),
                    config=cfg_d,
                )
            )
            metrics.append(
                Metric(
                    name=f"comm_{strategy}_density",
                    value=round(float(info.mean_density), 4),
                    metric="density", unit="phi", config=cfg_d,
                    direction="match", tolerance=0.05,
                )
            )
            t = time_fn(fn, buckets, err, srv, key, iters=iters)
            metrics.append(wall_metric(f"comm_{strategy}_step", t, config=cfg_d))
    # analytic wire models at the production world sizes (W = 16 data / 2 pods)
    for world in (2, 16):
        metrics.append(
            bytes_metric(
                f"comm_model_allgather_wire_w{world}",
                aggregation.bucketed_sign_allgather_wire_bytes(nb, bs, world),
                config={"world": world, "n_buckets": nb, "bucket_size": bs},
            )
        )
        metrics.append(
            bytes_metric(
                f"comm_model_alltoall_wire_w{world}",
                aggregation.bucketed_sign_alltoall_wire_bytes(nb, bs, world),
                config={"world": world, "n_buckets": nb, "bucket_size": bs},
            )
        )
    return metrics
