"""Convergence benches: the paper's §3 counterexamples (port of
benchmarks/counterexamples.py), the §5.2 Wilson least-squares generalization
run, and the A.1 sparse-noise toy. The counterexample endpoints are
deterministic given the seed, so the baseline gate pins the *qualitative*
claims: SIGNSGD ascends/stalls where EF-SIGNSGD descends."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.artifact import Metric
from repro.bench.registry import register_bench
from repro.core import ScaledSignCompressor, ef_step, init_ef_state


def _sgn(x):
    # the paper's sign operator: sign(0) = +1 (matches our compressors)
    return jnp.where(x >= 0, 1.0, -1.0)


def ce1(steps=4000, gamma=0.05, seed=0):
    """CE1: linear f with bimodal noise — SIGNSGD ascends, SGD/EF descend."""
    key = jax.random.PRNGKey(seed)
    res = {}
    for name in ("sgd", "signsgd", "ef_signsgd"):
        k = key
        x = jnp.float32(0.0)
        state = init_ef_state({"x": jnp.zeros(())})
        for _ in range(steps):
            k, sub = jax.random.split(k)
            g = jnp.where(jax.random.uniform(sub) < 0.25, 4.0, -1.0)
            if name == "sgd":
                x = x - gamma * g
            elif name == "signsgd":
                x = x - gamma * _sgn(g)
            else:
                out, state = ef_step(ScaledSignCompressor(), {"x": -gamma * g}, state)
                x = x + out["x"]
            x = jnp.clip(x, -1.0, 1.0)
        res[name] = float(x) / 4  # f(x) = x/4, optimum −0.25
    return res


def _ce2_grad(x, eps=0.5):
    # subgradient with the paper's sign(0)=+1 choice — at x₁=x₂ the
    # adversarial subgradient keeps sign(g)=±(1,−1) (paper §3, CE2)
    s1 = _sgn(x[0] + x[1])
    s2 = _sgn(x[0] - x[1])
    return s1 * eps * jnp.array([1.0, 1.0]) + s2 * jnp.array([1.0, -1.0])


def ce2(steps=800, eps=0.5):
    """CE2: non-smooth convex — SIGNSGD trapped on x₁+x₂=2 for ANY steps."""
    f = lambda x: eps * jnp.abs(x[0] + x[1]) + jnp.abs(x[0] - x[1])
    res = {}
    x = jnp.array([1.0, 1.0])
    for t in range(steps):
        x = x - 0.05 / np.sqrt(t + 1) * _sgn(_ce2_grad(x, eps))
    res["signsgd_f"] = float(f(x))
    res["signsgd_line"] = float(x[0] + x[1])  # stays 2.0 — trapped

    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    for t in range(steps):
        out, state = ef_step(ScaledSignCompressor(), {"x": -0.05 * _ce2_grad(x, eps)}, state)
        x = x + out["x"]
    res["ef_signsgd_f"] = float(f(x))
    return res


def ce3(steps=1500, eps=0.5, seed=0):
    """CE3: smooth least squares, batch-1 stochastic — SIGNSGD trapped a.s."""
    a1 = jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    a2 = -jnp.array([1.0, -1.0]) + eps * jnp.array([1.0, 1.0])
    f = lambda x: jnp.dot(a1, x) ** 2 + jnp.dot(a2, x) ** 2

    def g(x, key):
        pick = jax.random.uniform(key) < 0.5
        ai = jnp.where(pick, 1.0, 0.0) * a1 + jnp.where(pick, 0.0, 1.0) * a2
        return 4 * jnp.dot(ai, x) * ai

    res = {}
    key = jax.random.PRNGKey(seed)
    x = jnp.array([1.0, 1.0])
    for t in range(steps):
        key, sub = jax.random.split(key)
        x = x - 0.02 / np.sqrt(t + 1) * _sgn(g(x, sub))
    res["signsgd_f"] = float(f(x))

    key = jax.random.PRNGKey(seed)
    x = jnp.array([1.0, 1.0])
    state = init_ef_state({"x": x})
    for t in range(steps):
        key, sub = jax.random.split(key)
        out, state = ef_step(ScaledSignCompressor(), {"x": -0.02 * g(x, sub)}, state)
        x = x + out["x"]
    res["ef_signsgd_f"] = float(f(x))
    return res


def _match(name, value, *, tol, config=None, abs_tol=1e-2):
    # abs_tol keeps zero/near-zero endpoints (e.g. EF driving f to 0) gated on
    # the qualitative claim instead of exact float equality
    return Metric(
        name=name, value=round(float(value), 6), metric="objective", unit="f",
        config=config or {}, direction="match", tolerance=tol, abs_tolerance=abs_tol,
    )


@register_bench("counterexamples", suites=("convergence", "smoke"))
def counterexamples(ctx):
    """Fig. 1 claims as gated numbers. Endpoints are seed-deterministic but
    RNG streams drift across jax versions, so tolerances are loose — the gate
    still catches sign flips and order-of-magnitude breaks."""
    steps1, steps2, steps3 = (800, 300, 400) if ctx.fast else (4000, 800, 1500)
    r1 = ce1(steps=steps1, seed=ctx.seed)
    r2 = ce2(steps=steps2)
    r3 = ce3(steps=steps3, seed=ctx.seed)
    cfg = {"steps": [steps1, steps2, steps3]}
    return [
        _match("ce1_sgd_f", r1["sgd"], tol=1.0, config=cfg),
        _match("ce1_signsgd_f", r1["signsgd"], tol=1.0, config=cfg),
        _match("ce1_ef_signsgd_f", r1["ef_signsgd"], tol=1.0, config=cfg),
        # the trap line is exact: SIGNSGD cannot leave x₁+x₂=2
        _match("ce2_signsgd_trapline", r2["signsgd_line"], tol=1e-4, config=cfg, abs_tol=1e-4),
        _match("ce2_signsgd_f", r2["signsgd_f"], tol=0.5, config=cfg),
        _match("ce2_ef_signsgd_f", r2["ef_signsgd_f"], tol=1.0, config=cfg),
        _match("ce3_signsgd_f", r3["signsgd_f"], tol=0.5, config=cfg),
        _match("ce3_ef_signsgd_f", r3["ef_signsgd_f"], tol=1.0, config=cfg),
    ]


def wilson_run(steps: int = 4000, seed: int = 0):
    """§5.2 / Fig. 3: over-parameterized least squares, exact A.6 data gen.
    Tracks train/test loss and the distance of the iterate from the span of
    observed gradients (Theorem IV / Lemma 9: EF → min-norm solution)."""
    from repro.data.synthetic import wilson_least_squares

    data = wilson_least_squares(seed)
    a = jnp.asarray(data.a_train, jnp.float32)
    y = jnp.asarray(data.y_train, jnp.float32)
    at = jnp.asarray(data.a_test, jnp.float32)
    yt = jnp.asarray(data.y_test, jnp.float32)
    n, d = a.shape

    def train_loss(x):
        return jnp.mean((a @ x - y) ** 2)

    def test_loss(x):
        return float(jnp.mean((at @ x - yt) ** 2))

    grad = jax.jit(jax.grad(train_loss))

    def span_distance(x, gmat):
        coef, *_ = np.linalg.lstsq(gmat, np.asarray(x), rcond=None)
        return float(np.linalg.norm(np.asarray(x) - gmat @ coef))

    gmat = np.asarray(data.a_train).T  # gradients live in span(rows of A)

    results = {}
    lrs = {"sgd": 0.05, "signsgd": 0.002, "signum": 0.002, "ef_signsgd": 0.05}
    for name in ("sgd", "signsgd", "signum", "ef_signsgd"):
        lr = lrs[name]
        x = jnp.zeros((d,))
        m = jnp.zeros((d,))
        state = init_ef_state({"x": x})
        for t in range(steps):
            g = grad(x)
            if name == "sgd":
                x = x - lr * g
            elif name == "signsgd":
                x = x - lr * jnp.sign(g)
            elif name == "signum":
                m = g + 0.9 * m
                x = x - lr * jnp.sign(m)
            else:
                out, state = ef_step(ScaledSignCompressor(), {"x": -lr * g}, state)
                x = x + out["x"]
        results[name] = {
            "train_loss": float(train_loss(x)),
            "test_loss": test_loss(x),
            "span_dist": span_distance(x, gmat),
        }
    return results


@register_bench("wilson_generalization", suites=("convergence",))
def wilson_generalization(ctx):
    """§5.2 / Fig. 3: EF reaches the min-norm solution (span distance → 0)
    where sign methods generalize worse (port of benchmarks/generalization.py)."""
    steps = 1000 if ctx.fast else 4000
    res = wilson_run(steps=steps, seed=ctx.seed)
    metrics = []
    for name, r in res.items():
        cfg = {"algo": name, "steps": steps}
        metrics.append(_match(f"wilson_{name}_train", r["train_loss"], tol=1.0, config=cfg))
        metrics.append(_match(f"wilson_{name}_test", r["test_loss"], tol=0.5, config=cfg))
        metrics.append(_match(f"wilson_{name}_spandist", r["span_dist"], tol=1.0, config=cfg))
    return metrics


def sparse_noise_run(steps: int = 400, reps: int = 20, seed: int = 0):
    """Paper A.1 / Fig. 5: ½‖x‖² with N(0,100²) noise on coordinate 0 only."""
    from repro.data.synthetic import sparse_noise_grad

    d = 100
    lrs = {"sgd": 1e-3, "ef_signsgd": 1e-3, "signsgd": 1e-2, "scaled_signsgd": 1e-2}
    finals: dict[str, list[float]] = {k: [] for k in lrs}
    for rep in range(reps):
        key = jax.random.PRNGKey(seed * 1000 + rep)
        for name, lr in lrs.items():
            k = key
            x = jnp.ones((d,)) * 5.0
            state = init_ef_state({"x": x})
            for t in range(steps):
                k, sub = jax.random.split(k)
                g = sparse_noise_grad(sub, x)
                if name == "sgd":
                    x = x - lr * g
                elif name == "signsgd":
                    x = x - lr * jnp.sign(g)
                elif name == "scaled_signsgd":
                    x = x - lr * jnp.mean(jnp.abs(g)) * jnp.sign(g)
                else:
                    out, state = ef_step(ScaledSignCompressor(), {"x": -lr * g}, state)
                    x = x + out["x"]
            finals[name].append(float(0.5 * jnp.sum(x * x)))
    return {k: (float(np.mean(v)), float(np.std(v))) for k, v in finals.items()}


@register_bench("sparse_noise", suites=("convergence",))
def sparse_noise(ctx):
    """A.1 / Fig. 5: sign methods are FAST under sparse noise while SGD/EF
    share the slower rate (port of benchmarks/sparse_noise.py)."""
    reps = 3 if ctx.fast else 20
    res = sparse_noise_run(reps=reps, seed=ctx.seed)
    return [
        _match(f"sparsenoise_{k}_f", mean, tol=1.0, config={"algo": k, "reps": reps})
        for k, (mean, _std) in res.items()
    ]
