"""Overlap benches: schedule statics, ring wire/latency models, and the
pipelined-step latency/exposure measurement at W=4.

``python -m repro.bench run --suite overlap`` → BENCH_overlap.json. The
deterministic subset (schedule facts + analytic ring models) also rides in
``smoke``; the W=4 step measurement runs a 4-fake-device subprocess (the
same isolation pattern as tests/test_distributed.py) so the main process
keeps its single CPU device.

Exposure accounting: on CPU the fake-device collectives execute inline, so
the *measured* overlapped step can only tie the one-shot step — the wall
numbers pin exactly that (ratio ≈ 1). What the schedule buys on a real
interconnect is evaluated by feeding the measured per-stage components
(backward+compress time, exchange-stage time, per-group byte split) through
the pipeline latency model (:func:`repro.overlap.pipeline.exposure_report`):
``overlap_exposed_comm_us`` is the part of the serial comm bill the schedule
cannot hide, and must sit strictly below ``overlap_serial_comm_us``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from repro.bench.artifact import Metric
from repro.bench.measure import bytes_metric, time_fn, wall_metric
from repro.bench.registry import SkipBench, register_bench
from repro.core import aggregation

BUCKET_SIZE = 1 << 12  # 4096 elems — many buckets/groups on the reduced model
WORLD = 4
GROUPS = (2, 4)
# 10 Gb/s inter-pod reference wire, shared with the analytic latency models
REF_WIRE_BYTES_PER_US = aggregation.REF_WIRE_BYTES_PER_US


def _layout_and_schedule(arch: str, n_groups: int):
    from repro.comm import bucketize
    from repro.configs import get_config, reduced
    from repro.models import transformer
    from repro.overlap import build_schedule

    cfg = reduced(get_config(arch))
    shapes = jax.eval_shape(lambda k: transformer.init_params(cfg, k), jax.random.PRNGKey(0))
    layout = bucketize.build_layout(shapes, BUCKET_SIZE)
    return layout, build_schedule(layout, shapes, n_groups=n_groups)


@register_bench("overlap_schedule_static", suites=("overlap", "smoke"))
def overlap_schedule_static(ctx):
    """Schedule build cost + the static facts the pipeline hangs off: group
    count, byte balance, and the issue-order rank monotonicity."""
    arch = "llama3_2_1b"
    n_groups = 4
    t = time_fn(
        lambda: _layout_and_schedule(arch, n_groups),
        iters=3 if ctx.fast else 10, warmup=1,
    )
    layout, sched = _layout_and_schedule(arch, n_groups)
    cfg_d = {"arch": arch, "bucket_size": BUCKET_SIZE, "n_groups": n_groups}
    sizes = [g.wire_bytes for g in sched.groups]
    ranks = [g.rank for g in sched.groups]
    metrics = [
        wall_metric("overlap_schedule_build", t, config=cfg_d),
        Metric(
            name="overlap_schedule_n_groups", value=float(sched.n_groups),
            metric="layout", unit="groups", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="overlap_schedule_covered_buckets", value=float(sched.n_buckets),
            metric="layout", unit="buckets", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
        Metric(
            # greedy balance quality: worst/best group byte ratio (1.0 = perfect)
            name="overlap_schedule_byte_balance",
            value=round(max(sizes) / min(sizes), 4),
            metric="layout", unit="ratio", config=cfg_d,
            direction="lower", tolerance=0.25,
        ),
        Metric(
            # issue order must follow reverse-AD availability
            name="overlap_schedule_rank_monotone",
            value=float(all(a <= b for a, b in zip(ranks, ranks[1:]))),
            metric="layout", unit="bool", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
    ]
    return metrics


@register_bench("overlap_ring_models", suites=("overlap", "smoke"))
def overlap_ring_models(ctx):
    """Analytic ring wire/latency models (core/aggregation.py): per-step
    bytes × (W−1), cross-checked equal to the all-gather total — the
    deterministic gate for the ef_ring strategy."""
    layout, _ = _layout_and_schedule("llama3_2_1b", 4)
    nb, bs = layout.n_buckets, layout.bucket_size
    metrics = [
        bytes_metric(
            "overlap_model_ring_per_step_bytes",
            aggregation.bucketed_sign_ring_per_step_bytes(nb, bs),
            config={"n_buckets": nb, "bucket_size": bs},
        )
    ]
    for world in (2, WORLD, 16):
        ring = aggregation.bucketed_sign_ring_wire_bytes(nb, bs, world)
        ag = aggregation.bucketed_sign_allgather_wire_bytes(nb, bs, world)
        lat = aggregation.ring_latency_model(
            nb, bs, world, bytes_per_us=REF_WIRE_BYTES_PER_US
        )
        cfg_d = {"world": world, "n_buckets": nb, "bucket_size": bs}
        metrics.append(bytes_metric(f"overlap_model_ring_wire_w{world}", ring, config=cfg_d))
        metrics.append(
            Metric(
                name=f"overlap_model_ring_eq_allgather_w{world}",
                value=float(ring == ag),
                metric="model", unit="bool", config=cfg_d,
                direction="match", tolerance=0.0,
            )
        )
        metrics.append(
            Metric(
                name=f"overlap_model_ring_step_us_w{world}",
                value=round(lat["per_step_us"], 3),
                metric="model", unit="us",
                config=dict(cfg_d, bytes_per_us=REF_WIRE_BYTES_PER_US),
                direction="match", tolerance=0.01,
            )
        )
    return metrics


_DRIVER = r"""
import os, json, time, statistics
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST
from repro.comm import CommSpec, make_aggregator
from repro.overlap import build_schedule

BUCKET, ITERS, WORLD = %(bucket)d, %(iters)d, %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=WORLD, model=1)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)
comp = ScaledSignCompressor()
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}

def timeit(fn, *a):
    for _ in range(2):
        jax.block_until_ready(fn(*a))
    xs = []
    for _ in range(ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        xs.append((time.perf_counter() - t0) * 1e6)
    return {"median": statistics.median(xs), "min": min(xs)}

out = {}
with use_mesh(mesh):
    state0 = init_train_state(cfg, key, chain, "ef_allgather", mesh, ef_axes, bucket_size=BUCKET)
    def step_time(groups):
        from repro.configs.base import OverlapConfig
        spec = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=BUCKET,
            overlap=None if groups is None else OverlapConfig(n_groups=groups))
        bundle = ST.make_train_step(cfg, mesh, rules, spec=spec,
            local_chain=chain, ef_axes=ef_axes, batch_example=batch,
            state_example=state0)
        state = jax.device_put(state0, bundle.in_shardings[0])
        b = jax.device_put(batch, bundle.in_shardings[1])
        # no donation: the timed loop reuses the same state buffers
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings)
        return timeit(lambda: fn(state, b))
    out["oneshot"] = step_time(None)
    for g in %(groups)r:
        out["overlap_g%%d" %% g] = step_time(g)

    # exchange stage alone (encode + collective + decode) = the serial comm
    # bill the pipeline tries to hide
    from repro.comm import bucketize
    layout = bucketize.build_layout(state0.params, BUCKET)
    agg = make_aggregator(CommSpec(strategy="ef_allgather", compressor=comp,
                                   bucket_size=BUCKET), layout, mesh, ef_axes)
    rng = jax.random.PRNGKey(2)
    from jax.sharding import NamedSharding, PartitionSpec as P
    buckets_w = tuple(
        jax.device_put(jax.random.normal(jax.random.fold_in(rng, gi), (WORLD, g.n_buckets, BUCKET)),
                       NamedSharding(mesh, P("data")))
        for gi, g in enumerate(layout.groups))
    err_w = tuple(jnp.zeros_like(b) for b in buckets_w)
    jagg = jax.jit(agg)
    out["serial_comm"] = timeit(lambda: jagg(buckets_w, err_w, (), key))
    ring = jax.jit(make_aggregator(CommSpec(strategy="ef_ring", compressor=comp,
                                            bucket_size=BUCKET), layout, mesh, ef_axes))
    out["ring_comm"] = timeit(lambda: ring(buckets_w, err_w, (), key))
    sched = build_schedule(layout, state0.params, n_groups=max(%(groups)r))
    out["group_bytes"] = [g.wire_bytes for g in sched.groups]
print(json.dumps(out))
"""


@register_bench("overlap_step_latency", suites=("overlap",))
def overlap_step_latency(ctx):
    """Overlapped vs one-shot EF step at W=4 (subprocess, 4 fake devices):
    wall latency of both paths, the exchange stage alone, and the pipeline-
    model exposure of the measured components."""
    if jax.default_backend() != "cpu":
        raise SkipBench("subprocess driver assumes CPU fake devices")
    repo_src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    code = _DRIVER % {
        "src": repo_src, "bucket": BUCKET_SIZE, "world": WORLD,
        "iters": 5 if ctx.fast else 15, "groups": list(GROUPS),
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise RuntimeError(f"overlap driver failed: {proc.stderr[-2000:]}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    cfg_d = {"world": WORLD, "bucket_size": BUCKET_SIZE, "arch": "llama3_2_1b"}
    metrics = [
        wall_metric("overlap_oneshot_step", {**_t(out["oneshot"]), "iters": 0}, config=cfg_d),
        wall_metric("overlap_serial_comm", {**_t(out["serial_comm"]), "iters": 0}, config=cfg_d),
        wall_metric("overlap_ring_comm", {**_t(out["ring_comm"]), "iters": 0}, config=cfg_d),
    ]
    oneshot = out["oneshot"]["median"]
    serial_comm = out["serial_comm"]["median"]
    for g in GROUPS:
        t = out[f"overlap_g{g}"]
        metrics.append(
            wall_metric(f"overlap_step_g{g}", {**_t(t), "iters": 0}, config=dict(cfg_d, groups=g))
        )
        metrics.append(
            Metric(
                # same work, pipelined order: must not cost more than one-shot
                name=f"overlap_step_ratio_g{g}",
                value=round(t["min"] / out["oneshot"]["min"], 4),
                metric="ratio", unit="x", config=dict(cfg_d, groups=g),
                direction="lower", tolerance=0.20, abs_tolerance=0.10,
            )
        )
    # pipeline latency model on the measured components (backward+compress
    # span + serial exchange bill, split over the schedule by wire bytes)
    from repro.overlap import proportional_exposure

    gb = out["group_bytes"]
    rep = proportional_exposure(gb, max(oneshot - serial_comm, 0.0), serial_comm)
    metrics.append(
        Metric(
            name="overlap_exposed_comm_us", value=round(rep["exposed_us"], 1),
            metric="model", unit="us", config=dict(cfg_d, groups=len(gb)),
            direction="lower", tolerance=1.0,
        )
    )
    metrics.append(
        Metric(
            # the acceptance headline: exposure strictly below serial comm
            name="overlap_exposure_frac", value=round(rep["exposure_frac"], 4),
            metric="model", unit="fraction", config=dict(cfg_d, groups=len(gb)),
            direction="lower", tolerance=0.5, abs_tolerance=0.1,
        )
    )
    metrics.append(
        Metric(
            name="overlap_exposure_below_serial",
            value=float(rep["exposed_us"] < rep["serial_comm_us"]),
            metric="model", unit="bool", config=dict(cfg_d, groups=len(gb)),
            direction="match", tolerance=0.0,
        )
    )
    return metrics


def _t(d: dict) -> dict:
    return {"median_us": d["median"], "min_us": d["min"], "mean_us": d["median"]}
