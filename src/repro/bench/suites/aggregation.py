"""Aggregation-strategy benches: every strategy in core/aggregation.py run
under shard_map on the host mesh, with the wire-byte/density accounting from
the ``AggInfo`` dicts the strategies already emit, plus the §6.1 wire-bits
table over real parameter trees (port of benchmarks/compression.py)."""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.bench.artifact import Metric
from repro.bench.measure import bytes_metric, time_fn, wall_metric
from repro.bench.registry import register_bench
from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor, get_compressor, tree_wire_bits
from repro.launch.mesh import make_host_mesh
from repro.utils import compat

STRATEGIES = ("dense", "ef_allgather", "ef_alltoall", "majority_vote")


def _param_tree(seed: int = 0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "w1": jax.random.normal(k1, (256, 512)),
        "w2": jax.random.normal(k2, (512, 128)),
        "b": jax.random.normal(k3, (512,)),
    }


@register_bench("aggregation_strategies", suites=("aggregation", "smoke"))
def aggregation_strategies(ctx):
    """Per-strategy wall-clock + AggInfo wire-bytes/density on the host mesh
    (1 device → W=1; the multi-device path is covered by tests/test_distributed)."""
    mesh = make_host_mesh(data=1, model=1)
    updates = _param_tree(ctx.seed)
    n_params = sum(x.size for x in jax.tree.leaves(updates))
    comp = ScaledSignCompressor()
    metrics = []
    for strategy in STRATEGIES:
        state = aggregation.init_agg_state(strategy, updates, world=mesh.shape["data"])

        def body(u, s, _strategy=strategy):
            return aggregation.aggregate(_strategy, u, s, ("data",), comp)

        fn = jax.jit(
            compat.shard_map(
                body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P(), P()),
                manual_axes=("data",),
            )
        )
        out, new_state, info = fn(updates, state)
        jax.block_until_ready(out)
        d = aggregation.info_dict(info)
        cfg = {"strategy": strategy, "n_params": n_params, "world": mesh.shape["data"]}
        metrics.append(
            bytes_metric(f"agg_{strategy}_wire_bytes", d["wire_bytes_per_device"], config=cfg)
        )
        metrics.append(
            Metric(
                name=f"agg_{strategy}_density",
                value=round(d["mean_density"], 4),
                metric="density", unit="phi", config=cfg,
                direction="match", tolerance=0.05,
            )
        )
        iters = 3 if ctx.fast else 10
        t = time_fn(fn, updates, state, iters=iters)
        metrics.append(wall_metric(f"agg_{strategy}_step", t, config=cfg))
    # cross-check the analytic wire models against the emitted info: the dense
    # model is exact; the sign model is the single-leaf approximation of what
    # agg_ef_allgather_wire_bytes reports (exact: Σ leaves (dᵢ/8 + 4))
    world = mesh.shape["data"]
    metrics.append(
        bytes_metric("agg_dense_wire_model", aggregation.dense_wire_bytes(n_params))
    )
    metrics.append(
        bytes_metric(
            "agg_sign_allgather_wire_model",
            aggregation.sign_allgather_wire_bytes(n_params, world),
            config={"world": world},
        )
    )
    return metrics


@register_bench("wire_bits_accounting", suites=("aggregation", "smoke"))
def wire_bits_accounting(ctx):
    """§6.1's Σ(dᵢ+32)-bit claim over real parameter trees: exact wire bits
    for dense/sign/top-k/qsgd, plus the ~32× sign reduction ratio."""
    from repro.configs import ARCH_IDS, get_config, reduced
    from repro.models import transformer as T

    archs = ("llama3_2_1b",) if ctx.fast else tuple(ARCH_IDS)
    comps = {
        "dense": get_compressor("identity"),
        "sign": get_compressor("scaled_sign"),
        "top_k": get_compressor("top_k", k=64),
        "qsgd4bit": get_compressor("qsgd", s=7),
    }
    metrics = []
    for arch in archs:
        cfg = reduced(get_config(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        bits = {name: tree_wire_bits(c, params) for name, c in comps.items()}
        for name, b in bits.items():
            metrics.append(
                Metric(
                    name=f"wire_{arch}_{name}_bits", value=float(b),
                    metric="wire_bits", unit="bits",
                    config={"arch": arch, "compressor": name},
                    direction="match", tolerance=0.0,
                )
            )
        metrics.append(
            Metric(
                name=f"wire_{arch}_sign_reduction",
                value=round(bits["dense"] / bits["sign"], 2),
                metric="wire_bits", unit="ratio", config={"arch": arch},
                direction="higher", tolerance=0.01,
            )
        )
        # analytic full-size numbers: Σᵢ(dᵢ+32) with dᵢ the real leaf sizes
        full = get_config(arch)
        total, _ = full.param_counts()
        metrics.append(
            bytes_metric(f"wire_{arch}_full_dense_bytes", total * 4.0, config={"arch": arch})
        )
        metrics.append(
            bytes_metric(f"wire_{arch}_full_sign_bytes", total / 8.0, config={"arch": arch})
        )
    return metrics
