"""Federated-tier benches: wire-model exactness, convergence across
participation rates, cohort-scale wall time, and the million-client pool.

``python -m repro.bench run --suite fed`` → BENCH_fed.json. The headline is
the ISSUE's scale acceptance: a 10^6-client residual pool driven by a
10^4-client cohort runs as ONE compiled program per round — nothing in the
program scales with ``n_clients`` except the pool gather/scatter — with the
partial-participation persistence guarantee gated (rows of never-sampled
clients stay bitwise at the zero init) and the server's wire bill gated to
be independent of the population (only sampled clients pay).

All benches run the REAL round builder (:func:`repro.fed.round.make_fed_round`)
over the least-squares toy of the byz suite's convergence study — the model is
small so every byte and every row of the residual pool is attributable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.artifact import Metric
from repro.bench.measure import time_fn, wall_metric
from repro.bench.registry import register_bench
from repro.comm import bucketize
from repro.core import aggregation, optim
from repro.core.compressors import ScaledSignCompressor
from repro.fed import FedSpec, init_fed_state, make_fed_round
from repro.obs import telemetry as obs_telemetry

DIM = 128
BUCKET_SIZE = 64  # DIM = 2 buckets, % 32 == 0 for sign packing
LR = 0.1
ROUNDS = 40
TAIL = 10

# the million-client cell: one f32 pool row is nb·bs·4 = 128 B, so the full
# pool is 128 MB — sized to fit a CI runner while the cohort stays 10^4
MILLION = 1_000_000
MILLION_COHORT = 10_000
MILLION_BS = 32  # one bucket of 32 per client row


def _toy(n_elems=DIM, bucket=BUCKET_SIZE):
    """Per-client least-squares-style quadratic: client cid's optimum is a
    scaled ramp, so gradients are deterministic in cid and rounds are
    seed-stable across jax pins (no data RNG inside the round)."""
    params = {"w": jnp.zeros((n_elems,), jnp.float32)}
    layout = bucketize.build_layout(params, bucket)
    ramp = jnp.linspace(0.5, 1.5, n_elems)

    def grad_fn(p, b):
        def lf(q):
            r = q["w"] - b["target"]
            return 0.5 * jnp.sum(r * r), {}

        (loss, m), g = jax.value_and_grad(lf, has_aux=True)(p)
        return (loss, m), g

    def data_fn(idx, key, round_idx):
        return {"target": 0.01 * idx.astype(jnp.float32)[:, None] * ramp[None, :]}

    return params, layout, grad_fn, data_fn


def _match(name, value, *, tol=0.0, config=None, abs_tol=0.0, unit="bytes"):
    return Metric(
        name=name, value=round(float(value), 6), metric="value", unit=unit,
        config=config or {}, direction="match", tolerance=tol, abs_tolerance=abs_tol,
    )


def _gate(name, cond, *, config=None):
    return Metric(
        name=name, value=float(bool(cond)), metric="gate", unit="bool",
        config=config or {}, direction="match", tolerance=0.0,
    )


@register_bench("fed_wire_model", suites=("fed", "smoke"))
def bench_fed_wire_model(ctx):
    """In-graph billed bytes == the analytic fed wire model, exactly, and the
    bill is independent of the client population (only the cohort pays)."""
    params, layout, grad_fn, data_fn = _toy()
    chain = optim.sgd(LR)
    comp = ScaledSignCompressor()
    out = []
    for n, cohort in ((100, 10), (100_000, 10), (1000, 100)):
        spec = FedSpec(n_clients=n, cohort=cohort)
        rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
        state = init_fed_state(params, chain, layout, spec, seed=ctx.seed)
        _, (_, metrics) = rf(state)
        billed = float(metrics["wire_bytes"])
        modeled = obs_telemetry.modeled_fed_wire_bytes(layout, cohort, comp)
        closed = sum(
            aggregation.fed_round_wire_bytes(g.n_buckets, layout.bucket_size, cohort)
            for g in layout.groups
        )
        cfgd = {"n_clients": n, "cohort": cohort}
        out.append(_match(f"fed_wire_bytes_n{n}_c{cohort}", billed, config=cfgd))
        out.append(_gate(
            f"fed_wire_matches_model_n{n}_c{cohort}",
            billed == modeled == closed, config=cfgd,
        ))
    # same cohort, 1000x the population: identical bill
    out.append(_gate("fed_wire_independent_of_population",
                     out[0].value == out[2].value))
    return out


@register_bench("fed_participation_convergence", suites=("fed",))
def bench_fed_participation_convergence(ctx):
    """Tail loss across participation ∈ {1.0, 0.1, 0.01} on a 100-client
    population: every rate converges (EF keeps partial-participation rounds
    unbiased in the long run), lower participation pays proportionally fewer
    wire bytes per round."""
    params, layout, grad_fn, data_fn = _toy()
    chain = optim.sgd(LR)
    comp = ScaledSignCompressor()
    rounds = 15 if ctx.fast else ROUNDS
    out = []
    tails = {}
    for part in (1.0, 0.1, 0.01):
        spec = FedSpec(n_clients=100, participation=part)
        rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
        state = init_fed_state(params, chain, layout, spec, seed=ctx.seed)
        losses = []
        for _ in range(rounds):
            state, (loss, metrics) = rf(state)
            losses.append(float(loss))
        tail = float(np.mean(losses[-min(TAIL, rounds // 3):]))
        head = float(np.mean(losses[: rounds // 3]))
        tails[part] = tail
        cfgd = {"participation": part, "cohort": spec.cohort_size, "rounds": rounds}
        out.append(Metric(
            name=f"fed_tail_loss_p{part}", value=round(tail, 6), metric="objective",
            unit="loss", config=cfgd, direction="match", tolerance=0.05,
            abs_tolerance=1e-3,
        ))
        out.append(_gate(f"fed_converges_p{part}", tail < head, config=cfgd))
        out.append(_match(
            f"fed_round_bytes_p{part}", float(metrics["wire_bytes"]), config=cfgd,
        ))
    out.append(_gate(
        "fed_bytes_scale_with_participation",
        tails[1.0] is not None
        and obs_telemetry.modeled_fed_wire_bytes(layout, 1, comp) * 100
        == obs_telemetry.modeled_fed_wire_bytes(layout, 100, comp),
    ))
    return out


@register_bench("fed_cohort_scale_wall", suites=("fed",))
def bench_fed_cohort_scale_wall(ctx):
    """Steady-state round wall time as the cohort grows over a 10^4-client
    pool — the vmap'd cohort axis is the only axis that scales."""
    params, layout, grad_fn, data_fn = _toy()
    chain = optim.sgd(LR)
    comp = ScaledSignCompressor()
    n = 2_000 if ctx.fast else 10_000
    out = []
    for cohort in (16, 64) if ctx.fast else (16, 64, 256):
        spec = FedSpec(n_clients=n, cohort=cohort)
        rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
        state = init_fed_state(params, chain, layout, spec, seed=ctx.seed)

        def run(st):
            new, (loss, _) = rf(st)
            return new, loss

        state, _ = run(state)  # compile outside the timed region
        timing = time_fn(lambda: run(state)[1], iters=5 if ctx.fast else 10)
        out.append(wall_metric(
            f"fed_round_wall_n{n}_c{cohort}", timing,
            config={"n_clients": n, "cohort": cohort},
        ))
    return out


@register_bench("fed_million_clients", suites=("fed",))
def bench_fed_million_clients(ctx):
    """The scale acceptance: a 10^6-client EF residual pool, 10^4-client
    cohorts, ONE compiled program per round. Gates: the pool holds exact
    per-client state (touched rows != 0, never-sampled rows bitwise zero
    after 2 rounds), and the server bill equals the cohort model — no term
    scales with the million."""
    n = 100_000 if ctx.fast else MILLION
    cohort = 1_000 if ctx.fast else MILLION_COHORT
    params = {"w": jnp.zeros((MILLION_BS,), jnp.float32)}
    layout = bucketize.build_layout(params, MILLION_BS)
    ramp = jnp.linspace(0.5, 1.5, MILLION_BS)
    chain = optim.sgd(LR)
    comp = ScaledSignCompressor()

    def grad_fn(p, b):
        def lf(q):
            r = q["w"] - b["target"]
            return 0.5 * jnp.sum(r * r), {}

        (loss, m), g = jax.value_and_grad(lf, has_aux=True)(p)
        return (loss, m), g

    def data_fn(idx, key, round_idx):
        return {"target": 1e-5 * idx.astype(jnp.float32)[:, None] * ramp[None, :]}

    spec = FedSpec(n_clients=n, cohort=cohort)
    rf = jax.jit(make_fed_round(spec, layout, comp, chain, grad_fn, data_fn))
    state = init_fed_state(params, chain, layout, spec, seed=ctx.seed)
    state, (_, m1) = rf(state)
    timing = time_fn(lambda: rf(state)[1], iters=3, warmup=1)
    state, (_, m2) = rf(state)
    pool = np.asarray(state.residuals[0])
    touched = np.abs(pool).sum(axis=(1, 2)) > 0.0
    n_touched = int(touched.sum())
    cfgd = {"n_clients": n, "cohort": cohort, "bucket_size": MILLION_BS}
    pool_bytes = pool.size * 4
    return [
        _match("fed_million_pool_bytes", pool_bytes, config=cfgd),
        _match("fed_million_round_bytes", float(m2["wire_bytes"]), config=cfgd),
        _gate(
            "fed_million_bill_is_cohort_only",
            float(m1["wire_bytes"])
            == obs_telemetry.modeled_fed_wire_bytes(layout, cohort, comp),
            config=cfgd,
        ),
        # ≤ 2 rounds × cohort rows can be non-zero; every other row of the
        # million-row pool is still the bitwise zero init
        _gate("fed_million_persistence", 0 < n_touched <= 2 * cohort, config=cfgd),
        Metric(
            name="fed_million_touched_rows", value=float(n_touched),
            metric="count", unit="rows", config=cfgd, direction="info",
        ),
        wall_metric("fed_million_round_wall", timing, config=cfgd),
    ]
