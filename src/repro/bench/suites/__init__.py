"""Built-in benchmark suites. Importing this package registers every bench
(the registry imports it lazily on first lookup)."""

from repro.bench.suites import (  # noqa: F401
    aggregation,
    backends,
    byz,
    comm,
    convergence,
    fed,
    kernels,
    obs,
    overlap,
    roofline,
    serve,
)
