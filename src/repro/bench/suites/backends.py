"""Collective-backend suite: the pluggable transports head-to-head.

Three families of metrics, one committed baseline (``BENCH_backends.json``):

* ``backends_resolution_facts`` — deterministic registry behavior: what
  ``backend="auto"`` resolves to per strategy, and that an explicit
  ``pallas_dma`` off-TPU degrades to ``ring`` (the CI leg runs on CPU, so
  the fallback IS the pinned fact).
* ``backends_dma_model`` — the analytic DMA-hop latency model
  (:func:`repro.core.aggregation.dma_ring_latency_model`) at W ∈ {2, 4, 8}:
  per-hop cost, ring-vs-allgather totals, and the accept/reject verdict the
  ``auto`` promotion consults. Pure arithmetic → exact gate.
* ``backends_exchange_latency`` — measured: the same payload-mean exchange
  through every backend at W ∈ {2, 4, 8} on subprocess fake-device meshes,
  with a bitwise cross-backend equality bit per world size (the replicated
  out_specs contract) pinned alongside the wall clocks.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from repro.bench.artifact import Metric
from repro.bench.measure import bytes_metric, wall_metric
from repro.bench.registry import SkipBench, register_bench
from repro.core import aggregation

BUCKET_SIZE = 1 << 12  # 4096 elems — same granularity as the overlap suite
WORLDS = (2, 4, 8)


def _t(d: dict) -> dict:
    return {"median_us": d["median"], "min_us": d["min"], "mean_us": d["median"]}


@register_bench("backends_resolution_facts", suites=("backends", "smoke"))
def backends_resolution_facts(ctx):
    """Registry resolution pinned as data: auto defaults per strategy and the
    off-TPU ``pallas_dma`` → ``ring`` fallback."""
    from repro.comm import api, backends
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=1, model=1)
    metrics = [
        Metric(
            name="backends_registry_size", value=float(len(backends.BACKENDS)),
            metric="registry", unit="count",
            config={"names": sorted(backends.BACKENDS)},
            direction="match", tolerance=0.0,
        )
    ]
    for strategy, expect in (
        ("ef_ring", "ring"),
        ("ef_allgather", "xla"),  # CPU mesh: no pallas_dma promotion
        ("ef_coord_median", "xla"),
        ("dense", "xla"),
    ):
        spec = api.CommSpec(strategy=strategy, bucket_size=BUCKET_SIZE)
        got = backends.resolve(spec, mesh, ("data",)).name
        metrics.append(
            Metric(
                name=f"backends_auto_{strategy}",
                value=float(got == expect),
                metric="resolution", unit="bool",
                config={"strategy": strategy, "expect": expect, "got": got},
                direction="match", tolerance=0.0,
            )
        )
    # explicit pallas_dma off-TPU must degrade to the ppermute ring
    spec = api.CommSpec(strategy="ef_allgather", bucket_size=BUCKET_SIZE, backend="pallas_dma")
    got = backends.resolve(spec, mesh, ("data",)).name
    expect = "pallas_dma" if jax.default_backend() == "tpu" else "ring"
    metrics.append(
        Metric(
            name="backends_pallas_dma_fallback",
            value=float(got == expect),
            metric="resolution", unit="bool",
            config={"jax_backend": jax.default_backend(), "expect": expect, "got": got},
            direction="match", tolerance=0.0,
        )
    )
    return metrics


@register_bench("backends_dma_model", suites=("backends", "smoke"))
def backends_dma_model(ctx):
    """The accept/reject oracle, gated exactly: DMA-ring vs one-shot
    all-gather latency at the suite's world sizes (same bytes, different
    launch structure)."""
    nb = 64
    metrics = []
    for world in WORLDS + (16,):
        m = aggregation.dma_ring_latency_model(nb, BUCKET_SIZE, world)
        cfg_d = {"world": world, "n_buckets": nb, "bucket_size": BUCKET_SIZE,
                 "bytes_per_us": aggregation.REF_WIRE_BYTES_PER_US}
        metrics.append(
            bytes_metric(f"backends_dma_per_hop_bytes_w{world}", m["per_hop_bytes"], config=cfg_d)
        )
        metrics.append(
            Metric(
                name=f"backends_dma_total_us_w{world}", value=round(m["dma_total_us"], 3),
                metric="model", unit="us", config=cfg_d, direction="match", tolerance=0.01,
            )
        )
        metrics.append(
            Metric(
                name=f"backends_allgather_us_w{world}", value=round(m["allgather_us"], 3),
                metric="model", unit="us", config=cfg_d, direction="match", tolerance=0.01,
            )
        )
        metrics.append(
            Metric(
                name=f"backends_dma_accept_w{world}", value=float(m["accept"]),
                metric="model", unit="bool", config=cfg_d, direction="match", tolerance=0.0,
            )
        )
    return metrics


_DRIVER = r"""
import os, json, time, statistics
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, %(src)r)
import warnings
warnings.filterwarnings("ignore", category=DeprecationWarning)
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.comm import CommSpec, make_aggregator, bucketize
from repro.launch.mesh import make_host_mesh, use_mesh

BUCKET, ITERS, WORLD = %(bucket)d, %(iters)d, %(world)d
NB = 64
mesh = make_host_mesh(data=WORLD, model=1)
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (NB * BUCKET,), jnp.float32)}
layout = bucketize.build_layout(params, BUCKET)
buckets_w = tuple(
    jax.device_put(
        jax.random.normal(jax.random.fold_in(key, gi), (WORLD, g.n_buckets, BUCKET)),
        NamedSharding(mesh, P("data")))
    for gi, g in enumerate(layout.groups))
err_w = tuple(jnp.zeros_like(b) for b in buckets_w)

def timeit(fn, *a):
    for _ in range(2):
        jax.block_until_ready(fn(*a))
    xs = []
    for _ in range(ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        xs.append((time.perf_counter() - t0) * 1e6)
    return {"median": statistics.median(xs), "min": min(xs)}

out = {"timings": {}, "bitwise_equal": True}
ref = None
with use_mesh(mesh):
    for backend in ("xla", "ring", "pallas_dma"):
        spec = CommSpec(strategy="ef_allgather", bucket_size=BUCKET, backend=backend)
        agg = jax.jit(make_aggregator(spec, layout, mesh, ("data",)))
        res = agg(buckets_w, err_w, (), key)
        got = np.asarray(res[0][0])
        if ref is None:
            ref = got
        elif not np.array_equal(ref, got):
            out["bitwise_equal"] = False
        out["timings"][backend] = timeit(lambda: agg(buckets_w, err_w, (), key))
print(json.dumps(out))
"""


_ROBUST_XCHG_DRIVER = r"""
import os, json, time, statistics
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, %(src)r)
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.comm import CommSpec, make_aggregator, bucketize, robust
from repro.configs.base import ByzConfig
from repro.launch.mesh import make_host_mesh, use_mesh

BUCKET, ITERS, WORLD = %(bucket)d, %(iters)d, %(world)d
NB = 64
mesh = make_host_mesh(data=WORLD, model=1)
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (NB * BUCKET,), jnp.float32)}
layout = bucketize.build_layout(params, BUCKET)
buckets_w = tuple(
    jax.device_put(
        jax.random.normal(jax.random.fold_in(key, gi), (WORLD, g.n_buckets, BUCKET)),
        NamedSharding(mesh, P("data")))
    for gi, g in enumerate(layout.groups))
err_w = tuple(jnp.zeros_like(b) for b in buckets_w)

def timeit(fn, *a):
    for _ in range(2):
        jax.block_until_ready(fn(*a))
    xs = []
    for _ in range(ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        xs.append((time.perf_counter() - t0) * 1e6)
    return {"median": statistics.median(xs), "min": min(xs)}

out = {}
with use_mesh(mesh):
    for strategy in robust.ROBUST_STRATEGIES:
        rec = {"timings": {}, "bitwise_equal": True}
        ref = None
        for backend in ("xla", "ring", "pallas_dma"):
            spec = CommSpec(strategy=strategy, bucket_size=BUCKET, backend=backend,
                            byz=ByzConfig(f=1))
            agg = jax.jit(make_aggregator(spec, layout, mesh, ("data",)))
            res = agg(buckets_w, err_w, (), key)
            got = np.asarray(res[0][0])
            if ref is None:
                ref = got
            elif not np.array_equal(ref, got):
                rec["bitwise_equal"] = False
            rec["timings"][backend] = timeit(lambda: agg(buckets_w, err_w, (), key))
        out[strategy] = rec
print(json.dumps(out))
"""


@register_bench("backends_robust_exchange", suites=("backends",))
def backends_robust_exchange(ctx):
    """PR 10 slot-native exchange: the robust strategies through every
    transport — per-backend wall clocks plus the cross-backend bitwise
    equality bit at W ∈ {4, 8} under a declared byz_f=1 budget (2f < W,
    so W=2 has no robust cell; off-TPU the ``pallas_dma`` column measures
    its documented ring degrade)."""
    if jax.default_backend() != "cpu":
        raise SkipBench("subprocess driver assumes CPU fake devices")
    repo_src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    metrics = []
    for world in (4, 8):
        code = _ROBUST_XCHG_DRIVER % {
            "src": repo_src, "bucket": BUCKET_SIZE, "world": world,
            "iters": 3 if ctx.fast else 10,
        }
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, timeout=1200,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"robust backends driver (W={world}) failed: {proc.stderr[-2000:]}"
            )
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        for strategy, rec in out.items():
            cfg_d = {"world": world, "n_buckets": 64, "bucket_size": BUCKET_SIZE,
                     "strategy": strategy, "byz_f": 1}
            for backend, t in rec["timings"].items():
                metrics.append(
                    wall_metric(
                        f"backends_robust_{strategy}_{backend}_w{world}",
                        {**_t(t), "iters": 0},
                        config=dict(cfg_d, backend=backend),
                    )
                )
            metrics.append(
                Metric(
                    name=f"backends_robust_bitwise_{strategy}_w{world}",
                    value=float(rec["bitwise_equal"]),
                    metric="parity", unit="bool", config=cfg_d,
                    direction="match", tolerance=0.0,
                )
            )
    return metrics


@register_bench("backends_exchange_latency", suites=("backends",))
def backends_exchange_latency(ctx):
    """Measured payload-mean exchange per backend at W ∈ {2, 4, 8}
    (subprocess fake-device meshes), plus the bitwise cross-backend equality
    bit the replicated out_specs contract rests on. Off-TPU the
    ``pallas_dma`` column measures its documented ring fallback."""
    if jax.default_backend() != "cpu":
        raise SkipBench("subprocess driver assumes CPU fake devices")
    repo_src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    metrics = []
    for world in WORLDS:
        code = _DRIVER % {
            "src": repo_src, "bucket": BUCKET_SIZE, "world": world,
            "iters": 3 if ctx.fast else 10,
        }
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, timeout=1200,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
        )
        if proc.returncode != 0:
            raise RuntimeError(f"backends driver (W={world}) failed: {proc.stderr[-2000:]}")
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        cfg_d = {"world": world, "n_buckets": 64, "bucket_size": BUCKET_SIZE,
                 "strategy": "ef_allgather"}
        for backend, t in out["timings"].items():
            metrics.append(
                wall_metric(
                    f"backends_exchange_{backend}_w{world}", {**_t(t), "iters": 0},
                    config=dict(cfg_d, backend=backend),
                )
            )
        metrics.append(
            Metric(
                name=f"backends_bitwise_equal_w{world}",
                value=float(out["bitwise_equal"]),
                metric="parity", unit="bool", config=cfg_d,
                direction="match", tolerance=0.0,
            )
        )
    return metrics
