"""Kernel-stage benches: the fused EF-sign pipeline vs the unfused jnp
pipeline (port of benchmarks/kernels_bench.py), the decompress-mean hot loop,
and the modeled TPU HBM traffic. On CPU the Pallas path runs the jnp
reference; a real Pallas-compile bench is registered for TPU and skips
elsewhere."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.artifact import Metric
from repro.bench.measure import time_fn, wall_metric
from repro.bench.registry import SkipBench, register_bench
from repro.core.compressors import ScaledSignCompressor
from repro.kernels import ops

_FAST_SIZES = (1 << 16, 1 << 18)
_FULL_SIZES = (1 << 16, 1 << 20, 1 << 23)
# speedup ratios are only gated at sizes whose timings are macro (tens of ms
# on CPU) — static, so the artifact's metric set never depends on machine speed
_SPEEDUP_MIN_N = 1 << 22


def _pipelines():
    comp = ScaledSignCompressor()

    @jax.jit
    def unfused(g, e, gamma):
        p = gamma * g + e
        payload = comp.compress(p)
        delta = comp.decompress(payload, g.shape[0])
        return payload.words, payload.scale, p - delta

    fused = lambda g, e, gamma: ops.ef_sign_step(g, e, gamma, force="ref")
    return unfused, fused


@register_bench("ef_sign_fused_vs_unfused", suites=("kernels", "smoke"))
def ef_sign_fused_vs_unfused(ctx):
    """Wall-clock of the fused EF-sign step vs the 4-pass jnp pipeline."""
    unfused, fused = _pipelines()
    sizes = _FAST_SIZES if ctx.fast else _FULL_SIZES
    iters = 5 if ctx.fast else 20
    metrics = []
    for n in sizes:
        g = jax.random.normal(jax.random.PRNGKey(0), (n,))
        e = jax.random.normal(jax.random.PRNGKey(1), (n,))
        gamma = jnp.float32(0.01)
        t_un = time_fn(unfused, g, e, gamma, iters=iters)
        t_fu = time_fn(fused, g, e, gamma, iters=iters)
        cfg = {"n": n}
        metrics.append(wall_metric(f"ef_sign_unfused_n{n}", t_un, config=cfg))
        metrics.append(wall_metric(f"ef_sign_fusedref_n{n}", t_fu, config=cfg))
        # a gated speedup ratio only makes sense on macro timings: the ratio
        # of two sub-ms micro measurements swings >2× with scheduler noise
        # (the wall metrics above still record the small sizes, and carry the
        # artifact's absolute micro-timing slack). min-of-k is the robust
        # estimator for the ratio.
        if n >= _SPEEDUP_MIN_N:
            metrics.append(
                Metric(
                    name=f"ef_sign_speedup_n{n}",
                    value=round(t_un["min_us"] / t_fu["min_us"], 3),
                    metric="speedup",
                    unit="ratio",
                    config=cfg,
                    direction="higher",
                    tolerance=0.5,
                )
            )
    return metrics


@register_bench("ef_sign_hbm_model", suites=("kernels", "smoke"))
def ef_sign_hbm_model(ctx):
    """Modeled HBM bytes/elem for the fused Pallas kernel vs composed XLA —
    deterministic, pinned by the baseline gate (see kernels/ops.py)."""
    fused = ops.modeled_hbm_bytes_per_elem(fused=True)
    unfused = ops.modeled_hbm_bytes_per_elem(fused=False)
    mk = lambda name, v: Metric(
        name=name, value=round(v, 3), metric="hbm_model", unit="bytes/elem",
        direction="match", tolerance=0.0,
    )
    return [
        mk("ef_sign_model_bytes_fused", fused),
        mk("ef_sign_model_bytes_unfused", unfused),
        Metric(
            name="ef_sign_model_traffic_ratio",
            value=round(unfused / fused, 3),
            metric="hbm_model", unit="ratio", direction="higher", tolerance=0.05,
        ),
    ]


@register_bench("decompress_mean", suites=("kernels",))
def decompress_mean(ctx):
    """The all-gather decode hot loop: mean of W sign payloads."""
    import numpy as np

    metrics = []
    for w in (4, 16):
        rows = 256
        rng = np.random.default_rng(w)
        words = jnp.asarray(rng.integers(0, 2**32, size=(w, rows, 32), dtype=np.uint32))
        scales = jnp.asarray(np.abs(rng.normal(size=(w,))).astype(np.float32))
        fn = lambda a, b: ops.decompress_mean(a, b, force="ref")
        t = time_fn(fn, words, scales, iters=10)
        metrics.append(wall_metric(f"decompress_mean_w{w}_rows{rows}", t, config={"w": w, "rows": rows}))
    return metrics


@register_bench("ef_sign_pallas_compile", suites=("kernels",))
def ef_sign_pallas_compile(ctx):
    """Compiled (non-interpret) Pallas EF-sign step — TPU only; skips on
    CPU/GPU the same way the tpu pytest marker does."""
    if jax.default_backend() != "tpu":
        raise SkipBench("Pallas compile path needs a TPU backend")
    n = 1 << 20
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jax.random.normal(jax.random.PRNGKey(1), (n,))
    gamma = jnp.float32(0.01)
    fn = lambda g, e, gamma: ops.ef_sign_step(g, e, gamma, force="pallas")
    t = time_fn(fn, g, e, gamma, iters=20)
    return [wall_metric(f"ef_sign_pallas_n{n}", t, config={"n": n})]
