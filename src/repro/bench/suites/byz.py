"""Byzantine-robustness benches: analytic wire/decode-cost models for the
robust strategies plus a seed-deterministic adversarial convergence study.

The convergence bench runs a W=8 data-parallel least-squares problem through
the REAL comm primitives — vmap'd :func:`repro.comm.compressed.ef_encode_buckets`
per worker, the stacked payloads fed to :func:`decode_mean_buckets` /
:func:`repro.comm.robust.robust_combine`, attacks injected with
:func:`repro.comm.adversary.corrupt_worker_tree` — and gates the headline
claim: under a sign-flip attack on f=1 of W=8 workers the robust strategies
stay within 10% of the clean dense loss while ``ef_allgather`` and
``majority_vote`` measurably degrade.

Run ``python -m repro.bench run --suite byz`` for the BENCH_byz.json artifact.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.bench.artifact import Metric
from repro.bench.measure import bytes_metric
from repro.bench.registry import SkipBench, register_bench
from repro.comm import adversary, compressed, robust
from repro.configs.base import ByzConfig
from repro.core import aggregation
from repro.core.compressors import ScaledSignCompressor

# ---- convergence study constants -------------------------------------------
# Two measurement horizons, because the two mean-based failure modes live at
# different times: sign_flip is zero-mean at the optimum, so it never shifts a
# fixed point — it scales the effective allgather gradient by (W-2)/W, which
# only shows MID-decay (T_MID: clean dense ~35% above the sigma_test^2 ~ 0.09
# test floor, attacked mean an e^{0.25*2*lr*T} factor higher up the curve).
# Majority vote's failure is the opposite: its constant-lr sign floor (plus
# the attack's pivotal-vote bias) sits ~40% above dense's floor, visible only
# once runs HAVE converged (T_LONG). Gate ratios are tail-averaged over the
# last TAIL iterates and averaged over INNER_SEEDS independent streams so the
# booleans survive cross-jax-pin RNG drift (measured cross-seed spread is
# ~2-3% per cell; gate margins are 5%+).
W = 8
DIM = 128
N_BUCKETS = 2
BUCKET_SIZE = 64  # DIM = N_BUCKETS * BUCKET_SIZE, % 32 == 0 for sign packing
BATCH = 32
N_TEST = 512
SIGMA_TRAIN = 0.5
SIGMA_TEST = 0.3
LR = 0.015
MV_LR = LR  # majority vote: unscaled sign votes at the shared step size
STEPS_MID, TAIL_MID = 60, 15
STEPS_LONG, TAIL_LONG = 100, 30
INNER_SEEDS = 3


def _run_one(strategy: str, attack: str | None, *, steps: int, seed: int, tail: int = 1) -> float:
    """Test loss of one (strategy, attack) cell, tail-averaged over the last
    ``tail`` iterates (endpoint wobble is the dominant noise source of the
    gate ratios). Fully jitted scan."""
    key = jax.random.PRNGKey(seed)
    kx, kt, kn, kdata = jax.random.split(key, 4)
    x_star = jax.random.normal(kx, (DIM,)) / jnp.sqrt(DIM)
    a_test = jax.random.normal(kt, (N_TEST, DIM))
    y_test = a_test @ x_star + SIGMA_TEST * jax.random.normal(kn, (N_TEST,))
    comp = ScaledSignCompressor()
    byz = ByzConfig(attack=attack, fraction=1.0 / W, f=1) if attack else None
    is_ef = strategy.startswith("ef_")

    def worker_grads(x, k):
        # fresh IID least-squares data per step and per worker: W honest
        # shards of the same distribution (heterogeneous shards would bias
        # the coordinate median by more than the attack biases the mean)
        ka, kb = jax.random.split(k)
        a = jax.random.normal(ka, (W, BATCH, DIM))
        y = jnp.einsum("wbd,d->wb", a, x_star) + SIGMA_TRAIN * jax.random.normal(kb, (W, BATCH))
        r = jnp.einsum("wbd,d->wb", a, x) - y
        return (2.0 / BATCH) * jnp.einsum("wb,wbd->wd", r, a)

    def step(carry, t):
        x, e_w = carry
        kg, katt = jax.random.split(jax.random.fold_in(kdata, t))
        g_w = worker_grads(x, kg)
        if byz is not None:
            g_w = adversary.corrupt_worker_tree(byz, {"g": g_w}, katt, world=W)["g"]
        if strategy == "dense":
            upd = LR * jnp.mean(g_w, axis=0)
        elif strategy == "majority_vote":
            upd = MV_LR * jnp.sign(jnp.sum(jnp.sign(g_w), axis=0))
        else:
            b_w = (LR * g_w).reshape(W, N_BUCKETS, BUCKET_SIZE)
            payload_w, e_w, _ = jax.vmap(
                lambda b, e: compressed.ef_encode_buckets(comp, b, e)
            )(b_w, e_w)
            gathered = compressed.BucketPayload(data=payload_w.data)
            if strategy == "ef_allgather":
                upd = compressed.decode_mean_buckets(comp, gathered, BUCKET_SIZE)
            else:
                upd = robust.robust_combine(strategy, comp, gathered, BUCKET_SIZE, byz_f=1)
            upd = upd.reshape(DIM)
        x = x - upd
        return (x, e_w), jnp.mean((a_test @ x - y_test) ** 2)

    e0 = jnp.zeros((W, N_BUCKETS, BUCKET_SIZE)) if is_ef else jnp.zeros((0,))
    _, losses = jax.lax.scan(step, (jnp.zeros((DIM,)), e0), jnp.arange(steps))
    return float(jnp.mean(losses[-tail:]))


def _match(name, value, *, tol, config=None, abs_tol=1e-2):
    return Metric(
        name=name, value=round(float(value), 6), metric="objective", unit="loss",
        config=config or {}, direction="match", tolerance=tol, abs_tolerance=abs_tol,
    )


def _gate(name, cond, *, config=None):
    # acceptance booleans: exact-match 1.0-or-regress
    return Metric(
        name=name, value=float(bool(cond)), metric="gate", unit="bool",
        config=config or {}, direction="match", tolerance=0.0,
    )


def _cell(strategy, attack, *, steps, tail, seed, reps):
    vals = [
        _run_one(strategy, attack, steps=steps, seed=seed * 1000 + j, tail=tail)
        for j in range(reps)
    ]
    return sum(vals) / len(vals)


GRID_LONG = (
    ("dense", None),
    ("ef_allgather", None),
    ("ef_allgather", "sign_flip"),
    ("majority_vote", None),
    ("majority_vote", "sign_flip"),
    ("ef_coord_median", None),
    ("ef_coord_median", "sign_flip"),
    ("ef_trimmed_mean", None),
    ("ef_trimmed_mean", "sign_flip"),
    ("ef_trimmed_mean", "const_drift"),
    ("ef_trimmed_mean", "scaled_noise"),
    ("ef_norm_filter", None),
    ("ef_norm_filter", "sign_flip"),
    ("ef_norm_filter", "const_drift"),
)
GRID_MID = (("dense", None), ("ef_allgather", None), ("ef_allgather", "sign_flip"))


@register_bench("byz_convergence", suites=("byz",))
def byz_convergence(ctx):
    """W=8 adversarial least squares through the real encode/decode seam:
    tail-averaged losses per (strategy, attack) at both horizons, ratios vs
    clean dense, and the robust-within-10% / mean-degrades acceptance gates."""
    reps = 1 if ctx.fast else INNER_SEEDS
    sl, tl = (60, 15) if ctx.fast else (STEPS_LONG, TAIL_LONG)
    sm, tm = (36, 9) if ctx.fast else (STEPS_MID, TAIL_MID)
    long = {
        (s, a): _cell(s, a, steps=sl, tail=tl, seed=ctx.seed, reps=reps)
        for s, a in GRID_LONG
    }
    mid = {
        (s, a): _cell(s, a, steps=sm, tail=tm, seed=ctx.seed, reps=reps)
        for s, a in GRID_MID
    }
    base_cfg = {
        "world": W, "dim": DIM, "batch": BATCH, "lr": LR,
        "fraction": round(1.0 / W, 4), "f": 1, "reps": reps,
    }
    metrics = []
    for horizon, cells, steps in (("long", long, sl), ("mid", mid, sm)):
        dense = cells[("dense", None)]
        for (s, a), v in cells.items():
            tag = f"{s}_{a or 'clean'}_t{steps}"
            cfg = dict(base_cfg, strategy=s, attack=a, steps=steps)
            metrics.append(_match(f"byz_loss_{tag}", v, tol=0.5, config=cfg))
            if s != "dense":
                metrics.append(
                    Metric(
                        name=f"byz_ratio_{tag}", value=round(v / dense, 4),
                        metric="objective", unit="x_dense", direction="match",
                        tolerance=0.3, abs_tolerance=0.05, config=cfg,
                    )
                )
    # the ISSUE acceptance criteria, as hard booleans
    dense_long = long[("dense", None)]
    for s in robust.ROBUST_STRATEGIES:
        metrics.append(
            _gate(
                f"byz_gate_{s}_signflip_within10",
                long[(s, "sign_flip")] <= 1.10 * dense_long,
                config=dict(base_cfg, strategy=s, steps=sl),
            )
        )
    metrics.append(
        _gate(
            "byz_gate_ef_allgather_signflip_degrades",
            mid[("ef_allgather", "sign_flip")] >= 1.15 * mid[("dense", None)],
            config=dict(base_cfg, steps=sm),
        )
    )
    metrics.append(
        _gate(
            "byz_gate_majority_vote_signflip_degrades",
            long[("majority_vote", "sign_flip")] >= 1.15 * dense_long,
            config=dict(base_cfg, steps=sl),
        )
    )
    return metrics


@register_bench("byz_models", suites=("byz",))
def byz_models(ctx):
    """Analytic models: robust strategies pay exactly the allgather wire bill
    (robustness is decode-side) and the decode cost model's flops/bytes split."""
    nb, bs = 168, 16384  # llama3_2_1b-reduced-scale layout
    metrics = []
    for world in (4, 8, 16):
        cfg_d = {"world": world, "n_buckets": nb, "bucket_size": bs}
        robust_bytes = aggregation.bucketed_sign_robust_wire_bytes(nb, bs, world)
        metrics.append(
            bytes_metric(f"byz_model_robust_wire_w{world}", robust_bytes, config=cfg_d)
        )
        metrics.append(
            _gate(
                f"byz_model_wire_matches_allgather_w{world}",
                robust_bytes
                == aggregation.bucketed_sign_allgather_wire_bytes(nb, bs, world),
                config=cfg_d,
            )
        )
        for kind in robust.ROBUST_STRATEGIES:
            cost = aggregation.robust_decode_cost_model(nb, bs, world, byz_f=1, kind=kind)
            metrics.append(
                Metric(
                    name=f"byz_model_{kind}_flops_w{world}",
                    value=float(cost["total_flops"]), metric="flops", unit="flops",
                    config=dict(cfg_d, kind=kind), direction="match", tolerance=0.0,
                )
            )
        metrics.append(
            Metric(
                name=f"byz_model_stack_hbm_w{world}",
                value=float(
                    aggregation.robust_decode_cost_model(nb, bs, world)["stack_hbm_bytes"]
                ),
                metric="bytes", unit="bytes", config=cfg_d,
                direction="match", tolerance=0.0,
            )
        )
    return metrics


_BACKEND_PARITY_DRIVER = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, %(src)r)
import numpy as np
import jax, jax.numpy as jnp
from repro.comm import CommSpec, make_aggregator, bucketize, robust
from repro.configs.base import ByzConfig
from repro.launch.mesh import make_host_mesh, use_mesh

W = %(world)d
mesh = make_host_mesh(data=W, model=1)
rng = np.random.default_rng(11)
tree = {"w": jnp.zeros((512,), jnp.float32)}
layout = bucketize.build_layout(tree, 128)
buckets = bucketize.flatten_buckets(layout, tree)
grads = [tuple(jnp.asarray(rng.normal(size=(W,) + b.shape).astype(np.float32))
               for b in buckets) for _ in range(5)]
key = jax.random.PRNGKey(0)

def run(strategy, backend, telemetry="off"):
    spec = CommSpec(strategy=strategy, bucket_size=128, backend=backend,
                    byz=ByzConfig(f=1), telemetry=telemetry)
    with use_mesh(mesh):
        agg = jax.jit(make_aggregator(spec, layout, mesh, ("data",)))
        err = tuple(jnp.zeros_like(b) for b in grads[0])
        outs = info = None
        for g in grads:  # 5-step trajectory: EF residuals feed forward
            outs, err, _, info = agg(g, err, (), key)
        leaves = [np.asarray(x) for x in outs] + [np.asarray(x) for x in err]
        return leaves, info

out = {}
for strategy in robust.ROBUST_STRATEGIES:
    base, _ = run(strategy, "xla")
    rec = {}
    for backend in ("ring", "pallas_dma"):
        got, _ = run(strategy, backend)
        rec["parity_" + backend] = bool(
            all(np.array_equal(a, b) for a, b in zip(base, got)))
    lanes = []
    for backend in ("xla", "ring", "pallas_dma"):
        _, info = run(strategy, backend, telemetry="full")
        lanes.append(tuple(float(x) for x in np.asarray(info.telemetry.filtered_lanes)))
    rec["lanes_agree"] = len(set(lanes)) == 1
    out[strategy] = rec
print(json.dumps(out))
"""


@register_bench("byz_backend_parity", suites=("byz",))
def byz_backend_parity(ctx):
    """Robust × backend cells (PR 10 slot-native exchange): every robust
    strategy's 5-step EF aggregator trajectory at W=4, byz_f=1 is bitwise-
    equal on ring / pallas_dma (off-TPU degrade) to the xla gather, and the
    telemetry filtered-lane weights agree across all three transports —
    pinned as exact-match booleans."""
    if jax.default_backend() != "cpu":
        raise SkipBench("subprocess driver assumes CPU fake devices")
    repo_src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    world = 4
    code = _BACKEND_PARITY_DRIVER % {"src": repo_src, "world": world}
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise RuntimeError(f"byz backend-parity driver failed: {proc.stderr[-2000:]}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    metrics = []
    for strategy, rec in out.items():
        cfg_d = {"world": world, "bucket_size": 128, "strategy": strategy, "byz_f": 1}
        for backend in ("ring", "pallas_dma"):
            metrics.append(
                _gate(
                    f"byz_backend_parity_{strategy}_{backend}",
                    rec[f"parity_{backend}"],
                    config=dict(cfg_d, backend=backend),
                )
            )
        metrics.append(
            _gate(
                f"byz_backend_lanes_agree_{strategy}",
                rec["lanes_agree"],
                config=cfg_d,
            )
        )
    return metrics
