"""Observability benches: telemetry overhead gate + run-record invariants.

``python -m repro.bench run --suite obs`` → BENCH_obs.json. The headline
metric is the tentpole's acceptance gate: a W=4 subprocess compiles + times
the same bucketed ``ef_allgather`` train step with ``telemetry="off"`` vs
``"full"`` and telemetry must add ≤ 2% overhead. The gate compares the two
compiled programs' trip-count-aware HLO costs (dot flops / HBM bytes via
``repro.utils.hlo`` — deterministic and run-to-run stable); interleaved wall
clock for both is recorded next to it with a noise-band tolerance, since
shared CPU runners swing ±3% block to block, above the bound being gated. The deterministic rest pins the
run-record contract: schema field count, in-graph wire bytes equal to the
analytic model, density inside the unit interval, and the report CLI seeing
no wire mismatch.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from repro.bench.artifact import Metric
from repro.bench.measure import wall_metric
from repro.bench.registry import SkipBench, register_bench

BUCKET_SIZE = 1 << 12
WORLD = 4
OVERHEAD_GATE = 1.02  # telemetry-on step wall ≤ 2% over telemetry-off

_DRIVER = r"""
import os, json, time, statistics
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(world)d"
import sys
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch.mesh import make_host_mesh, ef_axis_names, use_mesh
from repro.sharding.rules import ShardingRules
from repro.train.state import init_train_state
from repro.train import steps as ST
from repro.comm import CommSpec, bucketize
from repro.obs.telemetry import modeled_wire_bytes
from repro.utils import hlo as hlo_util

BUCKET, ITERS, WORLD = %(bucket)d, %(iters)d, %(world)d
cfg = reduced(get_config("llama3_2_1b"))
mesh = make_host_mesh(data=WORLD, model=1)
rules = ShardingRules(cfg, mesh, "tp")
ef_axes = ef_axis_names(mesh, "tp")
chain = optim.sgd(0.02)
comp = ScaledSignCompressor()
key = jax.random.PRNGKey(0)
# a realistic training shape (batch 8 x seq 256): the gate is telemetry
# overhead relative to a REALISTIC step — a toy batch would shrink the
# denominator and overstate the fixed per-step telemetry reductions
batch = {"tokens": jax.random.randint(key, (8, 256), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 256), 0, cfg.vocab_size)}

def one_call(fn, *a):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) * 1e6

out = {}
with use_mesh(mesh):
    state0 = init_train_state(cfg, key, chain, "ef_allgather", mesh, ef_axes, bucket_size=BUCKET)
    layout = bucketize.build_layout(state0.params, BUCKET)
    out["modeled_wire_bytes"] = modeled_wire_bytes("ef_allgather", layout, WORLD, comp)
    fns = {}
    for level in ("off", "full"):
        spec = CommSpec(strategy="ef_allgather", compressor=comp, bucket_size=BUCKET,
                        telemetry=level)
        bundle = ST.make_train_step(cfg, mesh, rules, spec=spec,
            local_chain=chain, ef_axes=ef_axes, batch_example=batch,
            state_example=state0)
        state = jax.device_put(state0, bundle.in_shardings[0])
        b = jax.device_put(batch, bundle.in_shardings[1])
        # no donation: the timed loop reuses the same state buffers
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings)
        # trip-count-aware accounting (repro.utils.hlo): XLA's cost_analysis
        # counts the scan-over-layers body ONCE, underreporting the step ~12x
        # and inflating telemetry's relative share by the same factor
        parsed = hlo_util.analyze(fn.lower(state, b).compile().as_text())
        fns[level] = (fn, state, b)
        out["cost_" + level] = {"flops": float(parsed["dot_flops"]),
                                "bytes": float(parsed["hbm_bytes"])}
    for fn, state, b in fns.values():
        for _ in range(3):
            jax.block_until_ready(fn(state, b))
    # interleave the two programs round by round so slow machine drift
    # (thermal, CI co-tenants) hits both sides equally — the gate is a
    # 2%% ratio, far below the block-to-block wall variance on shared CPUs
    xs = {"off": [], "full": []}
    for _ in range(ITERS):
        for level, (fn, state, b) in fns.items():
            xs[level].append(one_call(fn, state, b))
    for level, s in xs.items():
        out[level] = {"median": statistics.median(s), "min": min(s)}
    fn, state, b = fns["full"]
    _, (_, metrics) = fn(state, b)
    t = metrics["obs"]
    out["telemetry"] = {
        "wire_bytes": float(t.wire_bytes),
        "density": [float(x) for x in t.density],
        "err_l2": [float(x) for x in t.err_l2],
        "group_bytes_sum": float(jnp.sum(t.group_bytes)),
    }
print(json.dumps(out))
"""


@register_bench("obs_telemetry_overhead", suites=("obs",))
def obs_telemetry_overhead(ctx):
    """Telemetry-on vs -off bucketed EF step at W=4 (subprocess, 4 fake
    devices): the ≤2%% compiled-cost overhead gate, interleaved wall times,
    and the in-graph-vs-model invariants measured on the same steps."""
    if jax.default_backend() != "cpu":
        raise SkipBench("subprocess driver assumes CPU fake devices")
    repo_src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    code = _DRIVER % {
        "src": repo_src, "bucket": BUCKET_SIZE, "world": WORLD,
        "iters": 5 if ctx.fast else 15,
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise RuntimeError(f"obs driver failed: {proc.stderr[-2000:]}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    cfg_d = {"world": WORLD, "bucket_size": BUCKET_SIZE, "arch": "llama3_2_1b"}
    wall_ratio = out["full"]["min"] / out["off"]["min"]
    # deterministic overhead: what telemetry ADDS to the compiled step, per
    # the trip-count-aware HLO cost model — wall clock on shared CPU runners
    # swings ±3% block to block, far above the 2% bound being gated, so the
    # precise gate is the cost ratio and the wall ratio gets a noise band
    cost_ratio = max(
        out["cost_full"]["flops"] / max(out["cost_off"]["flops"], 1.0),
        out["cost_full"]["bytes"] / max(out["cost_off"]["bytes"], 1.0),
    )
    tele = out["telemetry"]
    modeled = out["modeled_wire_bytes"]
    return [
        wall_metric("obs_step_telemetry_off", {**_t(out["off"]), "iters": 0}, config=cfg_d),
        wall_metric("obs_step_telemetry_full", {**_t(out["full"]), "iters": 0}, config=cfg_d),
        Metric(
            name="obs_telemetry_wall_ratio", value=round(wall_ratio, 4),
            metric="ratio", unit="x", config=cfg_d,
            direction="lower", tolerance=0.05, abs_tolerance=0.05,
        ),
        Metric(
            name="obs_telemetry_cost_ratio", value=round(cost_ratio, 6),
            metric="ratio", unit="x", config=cfg_d,
            direction="lower", tolerance=0.0, abs_tolerance=0.02,
        ),
        Metric(
            # THE acceptance gate: telemetry adds ≤2% to the compiled step's
            # flops and bytes-accessed (deterministic, run-to-run stable)
            name="obs_overhead_within_2pct", value=float(cost_ratio <= OVERHEAD_GATE),
            metric="gate", unit="bool", config=dict(cfg_d, gate=OVERHEAD_GATE),
            direction="match", tolerance=0.0,
        ),
        Metric(
            # in-graph accounting equals the analytic model EXACTLY
            name="obs_wire_model_match",
            value=float(tele["wire_bytes"] == modeled == tele["group_bytes_sum"]),
            metric="invariant", unit="bool", config=dict(cfg_d, modeled=modeled),
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="obs_density_in_unit",
            value=float(all(0.0 <= d <= 1.0 for d in tele["density"])),
            metric="invariant", unit="bool", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="obs_residual_finite",
            value=float(all(e == e and abs(e) != float("inf") for e in tele["err_l2"])),
            metric="invariant", unit="bool", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
    ]


@register_bench("obs_record_contract", suites=("obs",))
def obs_record_contract(ctx):
    """Run-record contract, no subprocess: schema shape, writer/reader
    round-trip, and the report CLI's wire-model cross-check on a synthetic
    in-spec run."""
    import tempfile

    from repro.obs import report as obs_report
    from repro.obs import sink as obs_sink
    from repro.obs.telemetry import telemetry_schema

    fields = telemetry_schema()
    meta = obs_sink.run_meta(
        config={"strategy": "ef_allgather", "world": 4},
        telemetry="full",
        modeled_wire_bytes=1024.0,
    )
    steps = [
        obs_sink.step_record(
            i,
            {"loss": 2.0 - 0.1 * i, "wire_bytes": 1024.0, "density": 0.5},
            walls={"step": 0.01},
        )
        for i in range(5)
    ]
    final = obs_sink.final_record(steps, steps=5, wall_s=0.05)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        with obs_sink.RunRecordWriter(path) as wr:
            for rec in [meta, *steps, final]:
                wr.write(rec)
        records = obs_sink.read_run(path)
        summary = obs_report.summarize(records)
    cfg_d = {"records": len(records)}
    return [
        Metric(
            name="obs_schema_n_fields", value=float(len(fields)),
            metric="schema", unit="fields", config={"schema": obs_sink.SCHEMA_VERSION},
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="obs_roundtrip_records", value=float(len(records)),
            metric="schema", unit="records", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="obs_report_no_anomalies", value=float(not summary["anomalies"]),
            metric="invariant", unit="bool", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
        Metric(
            name="obs_final_loss_present", value=float(summary["final_loss"] is not None),
            metric="invariant", unit="bool", config=cfg_d,
            direction="match", tolerance=0.0,
        ),
    ]


def _t(d: dict) -> dict:
    return {"median_us": d["median"], "min_us": d["min"], "mean_us": d["median"]}
