"""Serving benches: prefill latency + steady-state decode throughput through
the DecodeEngine (port of examples/serve_batched.py onto the engine's timed
path). Smoke runs the dense arch only; the full suite sweeps the dense, SSM,
and hybrid-MoE families the dry-run lowers for inference shapes."""

from __future__ import annotations

import dataclasses

import jax

from repro.bench.artifact import Metric
from repro.bench.measure import TIME_TOL
from repro.bench.registry import register_bench

_FAST_ARCHS = ("llama3.2-1b",)
_FULL_ARCHS = ("llama3.2-1b", "falcon-mamba-7b", "jamba-1.5-large-398b")


@register_bench("decode_throughput", suites=("serve", "smoke"))
def decode_throughput(ctx):
    """Batch-4 prefill + N-token greedy decode per architecture family."""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer
    from repro.serve.engine import DecodeEngine, ServeConfig

    archs = _FAST_ARCHS if ctx.fast else _FULL_ARCHS
    new_tokens = 8 if ctx.fast else 16
    mesh = make_host_mesh(data=1, model=1)
    metrics = []
    for arch in archs:
        cfg = dataclasses.replace(reduced(get_config(arch)), capacity_factor=4.0)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        engine = DecodeEngine(cfg, mesh, params, ServeConfig(max_len=96, temperature=0.0))
        prompts = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        }
        out, stats = engine.generate_timed(prompts, new_tokens=new_tokens)
        assert out.shape == (4, new_tokens)
        tag = arch.replace(".", "_").replace("-", "_")
        cfg_d = {"arch": arch, "batch": 4, "prompt_len": 16, "new_tokens": new_tokens}
        metrics.append(
            Metric(
                name=f"serve_{tag}_prefill", value=round(stats["prefill_us"], 1),
                metric="wall_time", unit="us", config=cfg_d,
                direction="lower", tolerance=TIME_TOL,
            )
        )
        metrics.append(
            Metric(
                name=f"serve_{tag}_decode_per_token", value=round(stats["decode_us_median"], 1),
                metric="wall_time", unit="us", config=cfg_d,
                direction="lower", tolerance=TIME_TOL,
            )
        )
        metrics.append(
            Metric(
                # derived 1:1 from the gated decode median — trajectory only,
                # a second gate on the same measurement would just double-flake
                name=f"serve_{tag}_tokens_per_s", value=round(stats["tokens_per_s"], 2),
                metric="throughput", unit="tok/s", config=cfg_d,
                direction="info",
            )
        )
    return metrics
