"""Roofline benches: summarize the dry-run JSON records written by
``repro.launch.dryrun`` / ``benchmarks.perf_iter`` (port of
benchmarks/roofline.py). The records themselves are produced out-of-process —
the dry-run needs 512 fake host devices, which must be configured before jax
init — so this suite only *reads*; it skips cleanly when no records exist."""

from __future__ import annotations

import glob
import json
import os

from repro.bench.artifact import Metric
from repro.bench.registry import SkipBench, register_bench

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "..", "benchmarks", "results", "dryrun"
)
HBM_PER_CHIP = 16 * 2**30  # v5e


def dryrun_record_path(out_dir: str, arch: str, shape: str, mesh: str = "single",
                       tag: str | None = None) -> str:
    """Canonical record filename — shared by perf_iter writers and this reader."""
    stem = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    return os.path.join(out_dir, stem + ".json")


def load_records(mesh: str = "single", tag: str | None = None, results_dir: str | None = None):
    results_dir = results_dir or RESULTS_DIR
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, f"*__{mesh}*.json"))):
        stem = os.path.basename(path)[: -len(".json")]
        parts = stem.split("__")
        if tag is None and len(parts) > 3:
            continue
        if tag is not None and (len(parts) < 4 or parts[3] != tag):
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(mesh="single", tag=None) -> str:
    """The EXPERIMENTS.md §Roofline table."""
    recs = load_records(mesh, tag)
    lines = [
        "| arch | shape | policy/strategy | compute_s | memory_s | collective_s "
        "| dominant | model/HLO flops | state+temp GiB/chip | fits? |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        m = r["memory"]
        state = m.get("argument_size_in_bytes", 0)
        temp = m.get("temp_size_in_bytes", 0)
        gib = (state + temp) / 2**30
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']}/{r['strategy']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| **{rf['dominant'].replace('_s','')}** "
            f"| {r['useful_flops_ratio']:.3f} | {gib:.1f} "
            f"| {'Y' if (state + temp) <= HBM_PER_CHIP else 'over'} |"
        )
    return "\n".join(lines)


@register_bench("roofline_records", suites=("roofline",))
def roofline_records(ctx):
    """Dominant roofline term + useful-FLOPs fraction per recorded combo."""
    recs = load_records("single")
    if not recs:
        raise SkipBench("no dry-run records under benchmarks/results/dryrun")
    metrics = []
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}"
        dom = r["roofline"]["dominant"]
        cfg = {"arch": r["arch"], "shape": r["shape"], "dominant": dom}
        metrics.append(
            Metric(
                name=f"{name}_{dom}", value=round(r["roofline"][dom], 4),
                metric="roofline", unit="s", config=cfg,
                direction="lower", tolerance=0.1,
            )
        )
        metrics.append(
            Metric(
                name=f"{name}_useful_flops", value=round(r["useful_flops_ratio"], 3),
                metric="roofline", unit="ratio", config=cfg,
                direction="higher", tolerance=0.05,
            )
        )
    return metrics
