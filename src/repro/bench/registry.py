"""Benchmark registry: ``@register_bench`` + suite lookup.

A *bench* is a named callable ``fn(ctx) -> list[Metric]`` registered into one
or more *suites* (``kernels``, ``aggregation``, ``convergence``, ``serve``,
``roofline``, ``smoke``). The ``smoke`` suite is the fast CI subset: a bench
registered in both its home suite and ``smoke`` receives ``ctx.fast=True``
when run as part of smoke and should scale its work down accordingly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

KNOWN_SUITES = (
    "kernels", "aggregation", "comm", "backends", "overlap", "byz", "fed", "convergence",
    "serve", "roofline", "obs", "smoke",
)


class SkipBench(Exception):
    """Raised by a bench body to skip cleanly (e.g. needs TPU, missing data)."""


@dataclasses.dataclass(frozen=True)
class BenchContext:
    """Runtime knobs passed to every bench body."""

    suite: str
    fast: bool = False
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Bench:
    name: str
    fn: Callable
    suites: tuple[str, ...]
    description: str = ""


_REGISTRY: dict[str, Bench] = {}


def register_bench(name: str, *, suites: tuple[str, ...] | list[str]):
    """Decorator: register ``fn(ctx) -> list[Metric]`` under ``name``."""
    suites = tuple(suites)
    if not suites:
        raise ValueError(f"bench {name!r} must belong to at least one suite")
    for s in suites:
        if s not in KNOWN_SUITES:
            raise ValueError(f"bench {name!r}: unknown suite {s!r} (known: {KNOWN_SUITES})")

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"bench {name!r} registered twice")
        _REGISTRY[name] = Bench(
            name=name, fn=fn, suites=suites, description=(fn.__doc__ or "").strip()
        )
        return fn

    return deco


def get_bench(name: str) -> Bench:
    _load_builtin_suites()
    if name not in _REGISTRY:
        raise KeyError(f"unknown bench {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def benches_for_suite(suite: str) -> list[Bench]:
    _load_builtin_suites()
    if suite not in KNOWN_SUITES:
        raise KeyError(f"unknown suite {suite!r} (known: {KNOWN_SUITES})")
    return sorted((b for b in _REGISTRY.values() if suite in b.suites), key=lambda b: b.name)


def all_benches() -> list[Bench]:
    _load_builtin_suites()
    return sorted(_REGISTRY.values(), key=lambda b: b.name)


_loaded = False


def _load_builtin_suites() -> None:
    """Import the built-in suite modules exactly once (they self-register)."""
    global _loaded
    if _loaded:
        return
    _loaded = True
    from repro.bench import suites  # noqa: F401  (import populates _REGISTRY)
