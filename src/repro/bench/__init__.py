"""repro.bench — registry-driven benchmark & regression subsystem.

Usage::

    PYTHONPATH=src python -m repro.bench run --suite smoke
    PYTHONPATH=src python -m repro.bench run --suite kernels --baseline BENCH_kernels.json
    PYTHONPATH=src python -m repro.bench list

Each run writes ``BENCH_<suite>.json`` (schema: repro/bench/artifact.py);
``--baseline`` gates the run against a previous artifact and exits nonzero on
regression. CI runs the ``smoke`` suite on every PR.
"""

from repro.bench.artifact import (
    Metric,
    Regression,
    compare,
    format_report,
    load_artifact,
    validate_document,
    write_artifact,
)
from repro.bench.measure import bytes_metric, time_fn, wall_metric
from repro.bench.registry import (
    Bench,
    BenchContext,
    KNOWN_SUITES,
    SkipBench,
    all_benches,
    benches_for_suite,
    get_bench,
    register_bench,
)
