"""BENCH_<suite>.json artifact schema, IO, and baseline comparison.

Schema (``schema_version`` 1)::

    {
      "schema_version": 1,
      "suite": "smoke",
      "git_sha": "abc123…" | null,
      "created_unix": 1700000000,
      "backend": "cpu",
      "metrics": [
        {"name": "...", "metric": "wall_time", "unit": "us", "value": 12.3,
         "config": {...}, "direction": "lower", "tolerance": 1.0}
      ]
    }

``direction`` states what counts as a regression against a baseline:
  * ``lower``  — bigger is worse (wall-clock, bytes moved)
  * ``higher`` — smaller is worse (speedups, throughput, accuracy)
  * ``match``  — any drift beyond tolerance is worse (deterministic values)
  * ``info``   — recorded for the trajectory, never gates (derived/noisy)

``tolerance`` is the per-metric relative slack and ``abs_tolerance`` (optional,
default 0) an absolute one — slack = tolerance·|base| + abs_tolerance, so
metrics with near-zero baselines can still be gated loosely. The *baseline's*
recorded values are authoritative when comparing (the run that set the bar
also set the slack).
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time

SCHEMA_VERSION = 1

DIRECTIONS = ("lower", "higher", "match", "info")


@dataclasses.dataclass(frozen=True)
class Metric:
    name: str
    value: float
    metric: str = "value"  # what was measured: wall_time / bytes / loss / ...
    unit: str = ""  # us, bytes, ratio, nats, ...
    config: dict = dataclasses.field(default_factory=dict)
    direction: str = "match"
    tolerance: float = 0.05  # relative slack in the bad direction
    abs_tolerance: float = 0.0  # absolute slack, for near-zero baselines

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"metric {self.name!r}: direction must be one of {DIRECTIONS}")
        if not (self.tolerance >= 0 and self.abs_tolerance >= 0):
            raise ValueError(f"metric {self.name!r}: tolerances must be >= 0")


@dataclasses.dataclass(frozen=True)
class Regression:
    name: str
    reason: str
    baseline: float | None
    current: float | None


def git_sha(cwd: str | None = None) -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):  # includes TimeoutExpired
        return None


def to_document(suite: str, metrics: list[Metric], *, backend: str | None = None) -> dict:
    if backend is None:
        try:
            import jax

            backend = jax.default_backend()
        except Exception:
            backend = "unknown"
    return {
        "schema_version": SCHEMA_VERSION,
        "suite": suite,
        "git_sha": git_sha(),
        "created_unix": int(time.time()),
        "backend": backend,
        "metrics": [dataclasses.asdict(m) for m in metrics],
    }


def artifact_path(suite: str, out_dir: str = ".") -> str:
    return os.path.join(out_dir, f"BENCH_{suite}.json")


def write_artifact(suite: str, metrics: list[Metric], out_dir: str = ".") -> str:
    path = artifact_path(suite, out_dir)
    os.makedirs(out_dir or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_document(suite, metrics), f, indent=1, sort_keys=False)
        f.write("\n")
    return path


def load_artifact(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    version = doc.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema_version {version!r} != {SCHEMA_VERSION}")
    if "metrics" not in doc or not isinstance(doc["metrics"], list):
        raise ValueError(f"{path}: missing metrics list")
    return doc


def validate_document(doc: dict) -> list[str]:
    """Structural check; returns a list of problems (empty == valid)."""
    problems = []
    for key in ("schema_version", "suite", "created_unix", "backend", "metrics"):
        if key not in doc:
            problems.append(f"missing top-level key {key!r}")
    for i, m in enumerate(doc.get("metrics", [])):
        for key in ("name", "metric", "unit", "value", "config", "direction", "tolerance"):
            if key not in m:
                problems.append(f"metric[{i}]: missing {key!r}")
        if m.get("direction") not in DIRECTIONS:
            problems.append(f"metric[{i}] {m.get('name')!r}: bad direction {m.get('direction')!r}")
        if not isinstance(m.get("value"), (int, float)):
            problems.append(f"metric[{i}] {m.get('name')!r}: non-numeric value")
    return problems


def legacy_rows(metrics: list[Metric]) -> list[tuple[str, float, float]]:
    """``(name, us_per_call, derived)`` rows for the old benchmarks.run CSV —
    wall-clock metrics land in the middle column, everything else in the last."""
    rows = []
    for m in metrics:
        if m.metric == "wall_time" and m.unit == "us":
            rows.append((m.name, m.value, 0.0))
        else:
            rows.append((m.name, 0.0, m.value))
    return rows


# wall-clock metrics get this much *absolute* slack on top of the relative
# tolerance: timings up to tens of ms are dominated by dispatch/scheduler
# noise (observed 16× swings under CPU contention), so they inform the
# artifact but only seriously-macro regressions can trip the gate
ABS_SLACK_US = 20000.0


def _is_regression(current: float, base: float, direction: str, tol: float,
                   abs_slack: float = 0.0) -> bool:
    if direction == "info":
        return False
    # tiny absolute floor so float noise never trips an exact-match gate
    slack = tol * abs(base) + 1e-9 + abs_slack
    if direction == "lower":
        return current > base + slack
    if direction == "higher":
        return current < base - slack
    return abs(current - base) > slack


def compare(current_doc: dict, baseline_doc: dict) -> list[Regression]:
    """Gate ``current_doc`` against ``baseline_doc``.

    A metric regresses when it moved beyond the baseline's recorded tolerance
    in its bad direction, or when it disappeared from the current run
    (coverage loss). Metrics new in the current run are fine.
    """
    current = {m["name"]: m for m in current_doc["metrics"]}
    regressions: list[Regression] = []
    for base in baseline_doc["metrics"]:
        name = base["name"]
        cur = current.get(name)
        if cur is None:
            regressions.append(
                Regression(name, "metric missing from current run", base["value"], None)
            )
            continue
        direction = base.get("direction", "match")
        tol = float(base.get("tolerance", 0.05))
        abs_slack = float(base.get("abs_tolerance", 0.0))
        if base.get("unit") == "us":
            abs_slack += ABS_SLACK_US
        if _is_regression(float(cur["value"]), float(base["value"]), direction, tol, abs_slack):
            regressions.append(
                Regression(
                    name,
                    f"{direction} violated beyond tol={tol:g}",
                    float(base["value"]),
                    float(cur["value"]),
                )
            )
    return regressions


def format_diff(current_doc: dict, baseline_doc: dict, *, markdown: bool = False) -> str:
    """Render a per-metric delta table between two artifacts.

    Covers the union of metric names: baseline metrics gate via
    :func:`compare` (status REGRESSION/ok/missing), current-only metrics show
    as ``new``. ``markdown=True`` emits a GitHub-flavored table for job
    summaries.
    """
    current = {m["name"]: m for m in current_doc["metrics"]}
    baseline = {m["name"]: m for m in baseline_doc["metrics"]}
    bad = {r.name for r in compare(current_doc, baseline_doc)}
    names = list(baseline) + [n for n in current if n not in baseline]
    rows = []
    for name in names:
        base, cur = baseline.get(name), current.get(name)
        if cur is None:
            status, delta = "MISSING", ""
        elif base is None:
            status, delta = "new", ""
        else:
            status = "REGRESSION" if name in bad else "ok"
            bv, cv = float(base["value"]), float(cur["value"])
            delta = f"{(cv - bv) / abs(bv) * 100.0:+.1f}%" if bv else f"{cv - bv:+.3g}"
        fmt = lambda m: "" if m is None else f"{float(m['value']):.6g}"
        direction = (base or cur).get("direction", "")
        rows.append((name, fmt(base), fmt(cur), delta, direction, status))
    header = ("metric", "baseline", "current", "delta", "direction", "status")
    if markdown:
        lines = [
            "| " + " | ".join(header) + " |",
            "|" + "|".join("---" for _ in header) + "|",
        ]
        lines += ["| " + " | ".join(r) + " |" for r in rows]
        n_bad = sum(r[5] in ("REGRESSION", "MISSING") for r in rows)
        lines.append("")
        lines.append(
            f"**{len(rows)} metrics, {n_bad} regression(s)**"
            if n_bad
            else f"**{len(rows)} metrics, no regressions**"
        )
        return "\n".join(lines)
    widths = [max(len(header[i]), *(len(r[i]) for r in rows)) if rows else len(header[i])
              for i in range(len(header))]
    line = lambda r: "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
    return "\n".join([line(header)] + [line(r) for r in rows])


def format_report(regressions: list[Regression]) -> str:
    if not regressions:
        return "baseline comparison: OK (no regressions)"
    lines = [f"baseline comparison: {len(regressions)} regression(s)"]
    for r in regressions:
        lines.append(f"  REGRESSION {r.name}: {r.reason} (baseline={r.baseline} current={r.current})")
    return "\n".join(lines)
