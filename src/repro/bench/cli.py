"""``python -m repro.bench`` — run suites, emit BENCH_<suite>.json, gate on a
baseline.

    run --suite smoke [--baseline BENCH_smoke.json] [--out DIR] [--only NAME]
    diff CURRENT BASELINE [--markdown]
    list

Exit codes: 0 ok · 1 regression vs baseline · 2 bench/usage error.
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.bench import artifact
from repro.bench.registry import BenchContext, SkipBench, all_benches, benches_for_suite


def run_suite(suite: str, *, only: str | None = None, seed: int = 0,
              log=print) -> tuple[list[artifact.Metric], int]:
    """Run every bench in ``suite``; returns (metrics, n_errors)."""
    ctx = BenchContext(suite=suite, fast=(suite == "smoke"), seed=seed)
    benches = benches_for_suite(suite)
    if only is not None:
        benches = [b for b in benches if b.name == only]
        if not benches:
            raise KeyError(f"bench {only!r} is not in suite {suite!r}")
    metrics: list[artifact.Metric] = []
    errors = 0
    for bench in benches:
        t0 = time.perf_counter()
        try:
            rows = bench.fn(ctx)
        except SkipBench as e:
            log(f"  SKIP {bench.name}: {e}")
            continue
        except Exception as e:  # one broken bench shouldn't hide the others
            log(f"  ERROR {bench.name}: {type(e).__name__}: {e}")
            errors += 1
            continue
        wall = time.perf_counter() - t0
        seen = {m.name for m in metrics}
        names = [m.name for m in rows]
        dupes = sorted(
            {n for n in names if n in seen} | {n for n in set(names) if names.count(n) > 1}
        )
        if dupes:
            log(f"  ERROR {bench.name}: duplicate metric names {dupes}")
            errors += 1
            continue
        metrics.extend(rows)
        log(f"  {bench.name}: {len(rows)} metrics in {wall:.1f}s")
    return metrics, errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    runp = sub.add_parser("run", help="run a suite and write BENCH_<suite>.json")
    runp.add_argument("--suite", required=True)
    runp.add_argument("--baseline", default=None,
                      help="gate against this artifact; exit 1 on regression")
    runp.add_argument("--out", default=".", help="artifact output dir (default: cwd)")
    runp.add_argument("--only", default=None, help="run a single bench from the suite")
    runp.add_argument("--seed", type=int, default=0)

    diffp = sub.add_parser(
        "diff", help="render per-metric deltas between two artifacts"
    )
    diffp.add_argument("current", help="artifact from the run under test")
    diffp.add_argument("baseline", help="reference artifact to diff against")
    diffp.add_argument(
        "--markdown", action="store_true",
        help="GitHub-flavored table (for $GITHUB_STEP_SUMMARY)",
    )

    sub.add_parser("list", help="list registered benches and their suites")

    args = ap.parse_args(argv)

    if args.cmd == "list":
        for b in all_benches():
            desc = b.description.splitlines()[0] if b.description else ""
            print(f"{b.name:32s} [{', '.join(b.suites)}] {desc}")
        return 0

    if args.cmd == "diff":
        try:
            current = artifact.load_artifact(args.current)
            baseline = artifact.load_artifact(args.baseline)
        except (OSError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        print(artifact.format_diff(current, baseline, markdown=args.markdown))
        return 1 if artifact.compare(current, baseline) else 0

    # resolve usage errors (unknown suite/bench, unreadable baseline) before
    # spending minutes running benches
    baseline = None
    try:
        if args.baseline:
            baseline = artifact.load_artifact(args.baseline)
        print(f"suite {args.suite}:")
        t0 = time.perf_counter()
        metrics, errors = run_suite(args.suite, only=args.only, seed=args.seed)
    except (KeyError, OSError, ValueError) as e:
        msg = str(e) if isinstance(e, OSError) else (e.args[0] if e.args else e)
        print(f"error: {msg}", file=sys.stderr)
        return 2
    path = artifact.write_artifact(args.suite, metrics, args.out)
    print(f"wrote {path} ({len(metrics)} metrics, {time.perf_counter() - t0:.1f}s)")

    rc = 2 if errors else 0
    if baseline is not None:
        regressions = artifact.compare(artifact.load_artifact(path), baseline)
        print(artifact.format_report(regressions))
        if regressions:
            rc = max(rc, 1)
    return rc


if __name__ == "__main__":
    sys.exit(main())
