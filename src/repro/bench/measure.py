"""Shared measurement utilities: warmup + median-of-k wall-clock with
``jax.block_until_ready`` fencing, and helpers for turning timings into
artifact metrics.

Wall-clock on shared CI machines is noisy; every timing metric defaults to a
wide tolerance (TIME_TOL, gate at 4×) so the baseline gate catches
order-of-magnitude slowdowns (a lost fusion, an accidental sync) without flaking on scheduler
jitter. Derived/deterministic quantities (bytes, ratios, losses) should use
``match``/tight tolerances instead — those are the precise part of the gate.
"""

from __future__ import annotations

import statistics
import time

import jax

from repro.bench.artifact import Metric

# default relative slack for wall-clock metrics on shared runners: a 4x
# slowdown gates, scheduler jitter and cross-runner CPU variance do not
TIME_TOL = 3.0


def time_fn(fn, *args, iters: int = 10, warmup: int = 2) -> dict:
    """Median-of-``iters`` wall-clock for ``fn(*args)`` in microseconds.

    Runs ``warmup`` untimed calls first (JIT compile + cache warm), fencing
    every timed call with ``jax.block_until_ready`` so async dispatch does not
    hide device time. Returns ``{"median_us", "min_us", "mean_us", "iters"}``.
    """
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return {
        "median_us": statistics.median(samples),
        "min_us": min(samples),
        "mean_us": statistics.fmean(samples),
        "iters": iters,
    }


def wall_metric(name: str, timing: dict, *, config: dict | None = None) -> Metric:
    """A ``Metric`` for a :func:`time_fn` result (median, lower-is-better)."""
    return Metric(
        name=name,
        value=round(timing["median_us"], 2),
        metric="wall_time",
        unit="us",
        config=dict(config or {}, iters=timing["iters"]),
        direction="lower",
        tolerance=TIME_TOL,
    )


def bytes_metric(name: str, value: float, *, config: dict | None = None,
                 direction: str = "match", tolerance: float = 0.0) -> Metric:
    """A bytes-moved accounting metric — deterministic, gated tightly."""
    return Metric(
        name=name,
        value=float(value),
        metric="bytes",
        unit="bytes",
        config=config or {},
        direction=direction,
        tolerance=tolerance,
    )
