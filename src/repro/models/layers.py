"""Shared neural building blocks (pure JAX — no flax offline).

Parameters are plain nested dicts; init functions mirror apply functions.
All matmuls run in ``compute_dtype`` with fp32 norms/softmax accumulation.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.act_sharding import constrain

Params = dict[str, Any]


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(d: int, norm_type: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, norm_type: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type == "rms":
        y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (1.0 / math.sqrt(d_in))
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def apply_embedding(p: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["table"].astype(compute_dtype)[tokens]


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, gated: bool, dtype, bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "in": init_linear(ks[0], d, d_ff, dtype, bias),
        "out": init_linear(ks[1], d_ff, d, dtype, bias),
    }
    if gated:
        p["gate"] = init_linear(ks[2], d, d_ff, dtype, bias)
    return p


def apply_mlp(p: Params, x: jax.Array, gated: bool) -> jax.Array:
    h = apply_linear(p["in"], x)
    if gated:
        h = jax.nn.silu(apply_linear(p["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return apply_linear(p["out"], h)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def init_attention(key, cfg) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, h * hd, dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], d, kv * hd, dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], d, kv * hd, dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], h * hd, d, dtype, False),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    window: int = 0,
    chunk: int = 512,
    q_chunk: int = 1024,
    window_slicing: bool = False,
) -> jax.Array:
    """Flash-style attention in XLA: double-chunked online softmax.

    * GQA kv heads are expanded to query heads first (``repeat_kv``) so the
      single head axis shards over ``model`` — without this the (Hkv, G)
      factorization left scores unsharded on a 16-way axis (8.6 GiB score
      blocks on the 398B config).
    * outer ``lax.map`` over query chunks with ``jax.checkpoint`` — backward
      recomputes scores per (q, kv) block instead of saving them (the flash
      trick, expressed in XLA).
    * inner ``lax.scan`` over KV chunks carries only (acc, m, l).

    The causal mask is applied per block (full-FLOPs baseline — block-skip
    is a §Perf item). ``window>0`` adds sliding-window masking.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    out_dtype = q.dtype
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    k = constrain(k, "b.m.")
    v = constrain(v, "b.m.")

    nk = (sk + chunk - 1) // chunk
    kpad = nk * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))

    q_chunk = min(q_chunk, sq)
    nq = (sq + q_chunk - 1) // q_chunk
    qpad = nq * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)

    # §Perf: static windowed KV slicing. With a sliding window only the last
    # (window + q_chunk) keys can be visible to a query chunk, so each q chunk
    # scans a fixed-length slice instead of all of Sk — attention work drops
    # from O(Sq·Sk) to O(Sq·window). (The masked-full path is the paper-
    # faithful baseline; see EXPERIMENTS.md §Perf.)
    slice_len = 0
    if window and window_slicing and causal and q_offset == 0:
        slice_len = min(((window + q_chunk + chunk - 1) // chunk) * chunk, nk * chunk)
        if slice_len >= nk * chunk:
            slice_len = 0  # window covers everything — no win

    def q_body(qi):
        qc = lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, axis=1)
        qc = constrain(qc.astype(jnp.float32) * scale, "b.m.")
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        if slice_len:
            start = jnp.clip((qi + 1) * q_chunk - slice_len, 0, nk * chunk - slice_len)
            kps = lax.dynamic_slice_in_dim(kp, start, slice_len, axis=1)
            vps = lax.dynamic_slice_in_dim(vp, start, slice_len, axis=1)
            nk_local = slice_len // chunk
        else:
            start = 0
            kps, vps, nk_local = kp, vp, nk

        def kv_body(carry, kidx):
            acc, m, l = carry
            kc = lax.dynamic_slice_in_dim(kps, kidx * chunk, chunk, axis=1)
            vc = lax.dynamic_slice_in_dim(vps, kidx * chunk, chunk, axis=1)
            k_pos = start + kidx * chunk + jnp.arange(chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc.astype(jnp.float32))
            mask = k_pos[None, :] < sk  # padding
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = constrain(jnp.where(mask[None, None], s, NEG_INF), "bm..")
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            acc = corr[..., None] * acc + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32)
            )
            return (acc, m_new, l_new), None

        acc0 = constrain(jnp.zeros((b, hq, q_chunk, hd), jnp.float32), "bm..")
        m0 = constrain(jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32), "bm.")
        l0 = constrain(jnp.zeros((b, hq, q_chunk), jnp.float32), "bm.")
        (acc, m, l), _ = lax.scan(kv_body, (acc0, m0, l0), jnp.arange(nk_local))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(out_dtype)  # (B, Cq, H, D)

    if nq == 1:
        out = q_body(jnp.int32(0))
    else:
        outs = lax.map(jax.checkpoint(q_body), jnp.arange(nq))  # (nq,B,Cq,H,D)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, hq, hd)
    return out[:, :sq]


def decode_attention(
    q: jax.Array,  # (B, 1, Hq, Dh)
    k_cache: jax.Array,  # (B, T, Hkv, Dh)
    v_cache: jax.Array,
    valid_mask: jax.Array,  # (B, T) bool — which cache slots participate
) -> jax.Array:
    """Single-token attention over a (possibly ring-buffer) KV cache."""
    b, _, hq, hd = q.shape
    hkv = k_cache.shape[2]
    group = hq // hkv
    qh = q.reshape(b, hkv, group, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhgd,bthd->bhgt", qh, k_cache.astype(jnp.float32))
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
