"""Unified decoder stack covering all assigned architecture families.

Layers are grouped into a repeating *pattern* of period
``lcm(hybrid_period, moe_every)`` (1 for homogeneous archs, 8 for jamba); the
stack is a ``lax.scan`` over pattern repeats with per-position parameter trees
stacked on a leading ``repeats`` axis. This keeps HLO size and compile time
O(period) instead of O(num_layers) — necessary for the 72-layer/398B config —
and gives remat a natural per-repeat granularity.

Caches follow the same layout: ``cache["blocks"][pos]`` holds stacked
per-repeat state (KV tensors for attn positions — full or ring-buffer
sliding-window — and (conv, ssm) state for mamba positions).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers, mamba, moe
from repro.models.act_sharding import constrain
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# pattern
# ---------------------------------------------------------------------------


def pattern_period(cfg: ModelConfig) -> int:
    hybrid = cfg.hybrid_period if cfg.arch_type == "hybrid" else 1
    moe_p = cfg.moe_every if (cfg.is_moe and cfg.moe_every > 1) else 1
    period = math.lcm(max(hybrid, 1), moe_p)
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return period


def layer_spec(cfg: ModelConfig, pos: int) -> dict:
    """Static description of pattern position ``pos``."""
    kind = cfg.layer_kind(pos)
    has_moe = cfg.layer_has_moe(pos)
    has_mlp = cfg.d_ff > 0 and not has_moe
    return {"kind": kind, "moe": has_moe, "mlp": has_mlp, "cross": cfg.cross_attention}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, spec: dict) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": layers.init_norm(cfg.d_model, cfg.norm_type, dtype)}
    if spec["kind"] == "attn":
        p["attn"] = layers.init_attention(ks[0], cfg)
    else:
        p["mamba"] = mamba.init_mamba(ks[0], cfg)
    if spec["cross"]:
        p["cross_norm"] = layers.init_norm(cfg.d_model, cfg.norm_type, dtype)
        p["cross"] = layers.init_attention(ks[1], cfg)
    if spec["moe"]:
        p["norm2"] = layers.init_norm(cfg.d_model, cfg.norm_type, dtype)
        p["moe"] = moe.init_moe(ks[2], cfg)
    elif spec["mlp"]:
        p["norm2"] = layers.init_norm(cfg.d_model, cfg.norm_type, dtype)
        p["mlp"] = layers.init_mlp(
            ks[2], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype, bias=cfg.norm_type == "layer"
        )
    return p


def _init_stack(key, cfg: ModelConfig, num_layers: int, cross: bool) -> list[Params]:
    """Per-position stacked block params: list[period] of (repeats, ...) trees."""
    period = pattern_period(cfg) if not cross else 1
    repeats = num_layers // period
    blocks = []
    for pos in range(period):
        spec = layer_spec(cfg, pos)
        if cross:  # encoder blocks: plain bidirectional attn + mlp
            spec = {"kind": "attn", "moe": False, "mlp": True, "cross": False}
        keys = jax.random.split(jax.random.fold_in(key, pos), repeats)
        stacked = jax.vmap(lambda k: _init_block(k, cfg, spec))(keys)
        blocks.append(stacked)
    return blocks


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p: Params = {
        "embed": layers.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": layers.init_norm(cfg.d_model, cfg.norm_type, dtype),
        "blocks": _init_stack(ks[1], cfg, cfg.num_layers, cross=False),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.init_linear(ks[2], cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.encoder_layers:
        # whisper-style encoder over stubbed frame embeddings
        enc_cfg = cfg
        p["encoder"] = {
            "blocks": _init_stack(ks[3], enc_cfg, cfg.encoder_layers, cross=True),
            "final_norm": layers.init_norm(cfg.d_model, cfg.norm_type, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_attn_sublayer(
    bp: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None,
    pos_scalar,
    memory: jax.Array | None,
):
    """Self-attention (train/prefill chunked, or decode over cache)."""
    h = layers.apply_norm(bp["norm1"], x, cfg.norm_type)
    a = bp["attn"]
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = layers._split_heads(layers.apply_linear(a["wq"], h), hq, hd)
    k = layers._split_heads(layers.apply_linear(a["wk"], h), hkv, hd)
    v = layers._split_heads(layers.apply_linear(a["wv"], h), hkv, hd)
    if cfg.rope_theta > 0:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and x.shape[1] == 1:
        # decode: write this token's kv into the (possibly ring) cache
        t_cache = cache["k"].shape[1]
        slot = pos_scalar % t_cache
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        slots = jnp.arange(t_cache)
        if cfg.sliding_window and t_cache == cfg.sliding_window:
            # ring buffer: all slots valid once it has wrapped
            valid = (slots <= pos_scalar) | (pos_scalar >= t_cache)
        else:
            valid = slots <= pos_scalar
        valid = jnp.broadcast_to(valid, (x.shape[0], t_cache))
        out = layers.decode_attention(q, kc, vc, valid)
        new_cache = {"k": kc, "v": vc}
    else:
        window = cfg.sliding_window
        out = layers.chunked_attention(
            q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
            window_slicing=cfg.attn_window_slicing,
        )
        if cache is not None:
            # prefill: populate the cache with the (windowed) trailing kv
            t_cache = cache["k"].shape[1]
            s = k.shape[1]
            if t_cache >= s:
                kc = lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1
                )
                vc = lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1
                )
            else:
                # ring buffer: keep last t_cache entries at slots (pos % t)
                tail_k = k[:, s - t_cache :, :, :]
                tail_v = v[:, s - t_cache :, :, :]
                idx = (jnp.arange(s - t_cache, s)) % t_cache
                kc = cache["k"].at[:, idx].set(tail_k.astype(cache["k"].dtype))
                vc = cache["v"].at[:, idx].set(tail_v.astype(cache["v"].dtype))
            new_cache = {"k": kc, "v": vc}
    y = layers.apply_linear(a["wo"], out.reshape(*out.shape[:-2], hq * hd))
    x = x + y

    if memory is not None and "cross" in bp:
        h = layers.apply_norm(bp["cross_norm"], x, cfg.norm_type)
        c = bp["cross"]
        qc = layers._split_heads(layers.apply_linear(c["wq"], h), hq, hd)
        kc_ = layers._split_heads(layers.apply_linear(c["wk"], memory), hkv, hd)
        vc_ = layers._split_heads(layers.apply_linear(c["wv"], memory), hkv, hd)
        out = layers.chunked_attention(qc, kc_, vc_, causal=False, chunk=cfg.attn_chunk)
        x = x + layers.apply_linear(c["wo"], out.reshape(*out.shape[:-2], hq * hd))
    return x, new_cache


def _apply_block(
    bp: Params,
    cfg: ModelConfig,
    spec: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None,
    pos_scalar,
    memory: jax.Array | None,
    causal: bool = True,
):
    """One pattern-position block. Returns (x, new_cache, aux)."""
    aux = {}
    if spec["kind"] == "attn":
        if not causal:
            # encoder block: bidirectional attention, no cache
            h = layers.apply_norm(bp["norm1"], x, cfg.norm_type)
            a = bp["attn"]
            hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
            q = layers._split_heads(layers.apply_linear(a["wq"], h), hq, hd)
            k = layers._split_heads(layers.apply_linear(a["wk"], h), hkv, hd)
            v = layers._split_heads(layers.apply_linear(a["wv"], h), hkv, hd)
            if cfg.rope_theta > 0:
                q = layers.apply_rope(q, positions, cfg.rope_theta)
                k = layers.apply_rope(k, positions, cfg.rope_theta)
            out = layers.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
            x = x + layers.apply_linear(a["wo"], out.reshape(*out.shape[:-2], hq * hd))
            new_cache = None
        else:
            x, new_cache = _apply_attn_sublayer(bp, cfg, x, positions, cache, pos_scalar, memory)
    else:
        h = layers.apply_norm(bp["norm1"], x, cfg.norm_type)
        y, new_state = mamba.apply_mamba(bp["mamba"], h, cfg, state=cache)
        x = x + y
        new_cache = new_state

    if spec["moe"]:
        h = layers.apply_norm(bp["norm2"], x, cfg.norm_type)
        y, aux = moe.apply_moe(bp["moe"], h, cfg)
        x = x + y
    elif spec["mlp"]:
        h = layers.apply_norm(bp["norm2"], x, cfg.norm_type)
        x = x + layers.apply_mlp(bp["mlp"], h, cfg.gated_mlp)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacks (scan over repeats)
# ---------------------------------------------------------------------------


def _zero_aux():
    return {"moe_aux_loss": jnp.float32(0.0), "moe_z_loss": jnp.float32(0.0)}


def _run_stack(
    blocks: list[Params],
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    caches: list | None,
    pos_scalar,
    memory: jax.Array | None,
    causal: bool = True,
    remat: bool = True,
):
    """scan over pattern repeats; returns (x, new_caches, aux_sum)."""
    period = len(blocks)
    specs = [
        layer_spec(cfg, p) if causal else {"kind": "attn", "moe": False, "mlp": True, "cross": False}
        for p in range(period)
    ]

    def repeat_body(carry, xs):
        x, aux = carry
        # sequence parallelism on the residual stream: remat saves one
        # (B, S, D) checkpoint per repeat — sharding S over 'model' cuts the
        # saved bytes 16× (Korthikanti-style SP; GSPMD re-gathers at matmuls)
        x = constrain(x, "bm." if cfg.residual_seq_shard else "b..")
        bps, cs = xs
        new_cs = []
        for pos in range(period):
            cache_pos = cs[pos] if cs is not None else None
            x, nc, a = _apply_block(
                bps[pos], cfg, specs[pos], x, positions, cache_pos, pos_scalar, memory, causal
            )
            new_cs.append(nc if nc is not None else (cache_pos if cache_pos is not None else 0))
            for k_ in aux:
                aux[k_] = aux[k_] + a.get(k_, 0.0)
        return (x, aux), tuple(new_cs) if cs is not None else 0

    body = jax.checkpoint(repeat_body) if remat else repeat_body
    if caches is None:

        def body_nocache(carry, bps):
            (x, aux), _ = body(carry, (bps, None))
            return (x, aux), 0

        (x, aux), _ = lax.scan(body_nocache, (x, _zero_aux()), tuple(blocks))
        return x, None, aux

    (x, aux), new_caches = lax.scan(body, (x, _zero_aux()), (tuple(blocks), tuple(caches)))
    return x, list(new_caches), aux


# ---------------------------------------------------------------------------
# public model API
# ---------------------------------------------------------------------------


def embed_inputs(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Token (+ stub-modality) embedding. Returns (x (B,S,D), loss_mask (B,S))."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    tok = batch["tokens"]
    x = layers.apply_embedding(params["embed"], tok, cdtype)
    mask = jnp.ones(tok.shape, jnp.float32)
    if cfg.num_patch_tokens and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cdtype)
        x = jnp.concatenate([pe, x], axis=1)
        mask = jnp.concatenate([jnp.zeros(pe.shape[:2], jnp.float32), mask], axis=1)
    return x, mask


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over stubbed (B, encoder_seq, D) frame embeddings."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdtype)
    positions = jnp.arange(x.shape[1])
    x, _, _ = _run_stack(
        params["encoder"]["blocks"], cfg, x, positions, None, 0, None, causal=False
    )
    return layers.apply_norm(params["encoder"]["final_norm"], x, cfg.norm_type)


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    cache: dict | None = None,
    pos: jax.Array | int = 0,
) -> tuple[jax.Array, dict | None, dict]:
    """Full-sequence forward (train / prefill).

    batch: tokens (B,S) [+ patch_embeds (B,P,D)] [+ frames (B,F,D)].
    Returns (logits (B,S',Vpad), new_cache, aux).
    """
    x, _ = embed_inputs(params, cfg, batch)
    positions = pos + jnp.arange(x.shape[1])
    memory = None
    if cfg.encoder_layers and "frames" in batch:
        memory = encode(params, cfg, batch["frames"])
    caches = cache["blocks"] if cache is not None else None
    x, new_caches, aux = _run_stack(
        params["blocks"], cfg, x, positions, caches, pos, memory
    )
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = _lm_head(params, cfg, x)
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["blocks"] = new_caches
        if memory is not None:
            new_cache["memory"] = memory.astype(cache.get("memory", memory).dtype)
    return logits, new_cache, aux


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: dict,
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,  # scalar int32 — current position
) -> tuple[jax.Array, dict]:
    """One-token decode against the cache. Returns (logits (B,1,V), cache)."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    x = layers.apply_embedding(params["embed"], tokens, cdtype)
    positions = pos + jnp.arange(1)
    memory = cache.get("memory")
    if memory is not None:
        memory = memory.astype(cdtype)
    x, new_caches, _ = _run_stack(
        params["blocks"], cfg, x, positions, cache["blocks"], pos, memory, remat=False
    )
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = _lm_head(params, cfg, x)
    new_cache = dict(cache)
    new_cache["blocks"] = new_caches
    return logits, new_cache


def _lm_head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].astype(x.dtype).T
    return layers.apply_linear(params["head"], x)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    with_memory: bool = False,
) -> dict:
    """Stacked per-pattern-position cache. Attn positions get (R,B,T,Hkv,Dh)
    KV buffers — T = sliding_window if configured and smaller, else max_len —
    mamba positions get (R,B,·) recurrent state."""
    period = pattern_period(cfg)
    repeats = cfg.num_layers // period
    blocks = []
    for posn in range(period):
        spec = layer_spec(cfg, posn)
        if spec["kind"] == "attn":
            t = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            c = {
                "k": jnp.zeros((repeats, batch, t, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((repeats, batch, t, cfg.num_kv_heads, cfg.head_dim), dtype),
            }
        else:
            c = {
                "conv": jnp.zeros((repeats, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32),
                "ssm": jnp.zeros((repeats, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
            }
        blocks.append(c)
    cache = {"blocks": blocks}
    if with_memory and cfg.encoder_layers:
        cache["memory"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
    return cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params: Params, cfg: ModelConfig, batch: dict):
    """Next-token cross entropy (fp32 logits) + MoE aux losses.

    batch["labels"] aligns with batch["tokens"]; VLM patch positions are
    excluded from the loss via the embed mask.
    """
    logits, _, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.num_patch_tokens and "patch_embeds" in batch:
        logits = logits[:, cfg.num_patch_tokens :, :]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss
    if cfg.is_moe:
        total = total + cfg.aux_loss_coef * aux["moe_aux_loss"] + cfg.router_z_coef * aux["moe_z_loss"]
    metrics = {"loss": loss, **aux}
    return total, metrics
