"""Unified model configuration covering all assigned architecture families.

One dataclass drives dense / MoE / SSM / hybrid / enc-dec / VLM construction;
``src/repro/configs/<arch>.py`` files instantiate it with the exact assigned
hyper-parameters (and cite their source).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

ArchType = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]

VOCAB_PAD = 256  # vocab padded up so embedding tables shard evenly on the mesh


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (num_heads == 0 → attention-free, pure SSM)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full attention; >0 = windowed (ring cache)
    attn_chunk: int = 512  # kv chunk for online-softmax attention
    attn_window_slicing: bool = True  # §Perf win (exact): static windowed KV slicing
    residual_seq_shard: bool = True  # §Perf: SP on the remat stream (DESIGN 5.1.3)
    ssm_chunk_remat: bool = True  # §Perf win (−61% mem on 398B): remat mamba chunks
    # mlp
    d_ff: int = 0
    gated_mlp: bool = True  # SwiGLU vs (whisper-style) GELU MLP
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE replaces the MLP every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 → ceil(d_model/16)
    # hybrid (jamba): repeating pattern of `hybrid_period` layers with one
    # attention layer at `hybrid_attn_index`; others are mamba blocks.
    hybrid_period: int = 0
    hybrid_attn_index: int = 4
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend emits this many frame embeddings
    cross_attention: bool = False
    learned_positions: bool = False  # whisper uses learned abs pos, no RoPE
    # VLM (llava): stubbed vision frontend emits this many patch embeddings
    num_patch_tokens: int = 0
    # norms / dtypes
    norm_type: Literal["rms", "layer"] = "rms"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # provenance
    source: str = ""

    # ------------------------------------------------------------------ #

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kind(self, idx: int) -> str:
        """'attn' or 'mamba' for decoder layer ``idx``."""
        if self.arch_type == "ssm":
            return "mamba"
        if self.arch_type == "hybrid":
            return "attn" if idx % self.hybrid_period == self.hybrid_attn_index else "mamba"
        return "attn"

    def layer_has_moe(self, idx: int) -> bool:
        return self.is_moe and (idx % self.moe_every == self.moe_every - 1 if self.moe_every > 1 else self.is_moe)

    # ---------------------------------------------------------------- #
    # parameter accounting (drives MODEL_FLOPS = 6·N·D in the roofline)
    # ---------------------------------------------------------------- #

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mlp_params(self) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * self.d_ff

    def _moe_params(self) -> tuple[int, int]:
        """(total, active) params of one MoE block."""
        mult = 3 if self.gated_mlp else 2
        per_expert = mult * self.d_model * self.d_ff
        router = self.d_model * self.num_experts
        total = self.num_experts * per_expert + router
        active = self.experts_per_token * per_expert + router
        return total, active

    def _mamba_params(self) -> int:
        d, di, st, dr = self.d_model, self.d_inner, self.ssm_state, self.dt_rank
        return (
            d * 2 * di  # in_proj
            + di * self.ssm_conv  # depthwise conv
            + di * (dr + 2 * st)  # x_proj
            + dr * di + di  # dt_proj (+bias)
            + di * st + di  # A_log, D
            + di * d  # out_proj
        )

    def param_counts(self) -> tuple[int, int]:
        """(total_params, active_params) of the decoder (+encoder) stack."""
        total = active = 0
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += self._attn_params()
                active += self._attn_params()
            else:
                total += self._mamba_params()
                active += self._mamba_params()
            if kind == "attn" or self.arch_type != "ssm":
                if self.layer_has_moe(i):
                    t, a = self._moe_params()
                    total, active = total + t, active + a
                elif self.d_ff:
                    total += self._mlp_params()
                    active += self._mlp_params()
            total += 2 * self.d_model  # norms
            active += 2 * self.d_model
        if self.encoder_layers:
            enc = self.encoder_layers * (self._attn_params() + self._mlp_params() + 2 * self.d_model)
            if self.cross_attention:
                total += self.num_layers * self._attn_params()  # decoder cross-attn
                active += self.num_layers * self._attn_params()
            total += enc
            active += enc
        emb = self.padded_vocab * self.d_model
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        return total, active

    def model_flops(self, tokens: int, forward_only: bool = False) -> float:
        """The roofline's MODEL_FLOPS: 6·N_active·D (training) or 2·N_active·D
        (forward-only: prefill and decode)."""
        _, active = self.param_counts()
        return (2.0 if forward_only else 6.0) * active * tokens


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
