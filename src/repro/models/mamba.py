"""Mamba-1 selective SSM block (Gu & Dao '23; falcon-mamba arXiv:2410.05355).

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is replaced by a
*chunked associative scan* — an outer ``lax.scan`` over time chunks carrying
the (B, d_inner, state) boundary state, with a parallel
``lax.associative_scan`` inside each chunk. This bounds the materialized
(time × d_inner × state) tensor to one chunk (the full-sequence variant is
~2 GB/example for falcon-mamba at 4k) while retaining within-chunk
parallelism for the VPU — the same blocking idea as the original kernel,
restructured for XLA/TPU instead of CUDA shared memory.

Decode: O(1) recurrent update carrying (conv window, ssm state).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers
from repro.models.act_sharding import constrain
from repro.models.config import ModelConfig

Params = dict[str, Any]


def init_mamba(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d, di, st, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.exp(jnp.clip(
        jnp.exp(jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)),
        1e-4, None)) - 1.0 + 1e-9)  # inverse-softplus of dt ~ LogUniform
    return {
        "in_proj": layers.init_linear(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di), jnp.float32) / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.init_linear(ks[3], di, dr + 2 * st, dtype),
        "dt_proj": layers.init_linear(ks[4], dr, di, dtype, bias=True),
        "dt_bias_init": dt_bias.astype(dtype),  # folded into dt_proj bias at init
        "A_log": jnp.log(a_init).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": layers.init_linear(ks[5], di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None):
    """Depthwise causal conv1d. x: (B,S,di); w: (K,di). state: (B,K-1,di) or None."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return out + b.astype(x.dtype), new_state


def _ssm_chunk_scan(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """h_t = a_t * h_{t-1} + bx_t within one chunk via associative scan.

    a, bx: (C, B, di, st); h0: (B, di, st) → (h_all (C,B,di,st), h_last).
    """
    bx = bx.at[0].add(a[0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h_all = lax.associative_scan(combine, (a, bx), axis=0)
    return h_all, h_all[-1]


def ssm_scan(
    dt: jax.Array,  # (B,S,di) — post-softplus
    a: jax.Array,  # (di,st) — negative continuous-time A
    b_t: jax.Array,  # (B,S,st)
    c_t: jax.Array,  # (B,S,st)
    x: jax.Array,  # (B,S,di)
    h0: jax.Array,  # (B,di,st)
    chunk: int = 128,
    chunk_remat: bool = False,
):
    """Selective scan, chunked. Returns (y (B,S,di), h_last)."""
    bsz, s, di = x.shape
    nchunks = max(1, (s + chunk - 1) // chunk)
    pad = nchunks * chunk - s

    def pad_t(z):
        return jnp.pad(z, ((0, 0), (0, pad)) + ((0, 0),) * (z.ndim - 2))

    dtp, btp, ctp, xp = pad_t(dt), pad_t(b_t), pad_t(c_t), pad_t(x)

    # discretize: ā = exp(dt·A) (ZOH on A), b̄x = dt·B_t·x_t
    def chunk_body(h, idx):
        sl = lambda z: lax.dynamic_slice_in_dim(z, idx * chunk, chunk, axis=1)
        dtc, btc, ctc, xc = sl(dtp), sl(btp), sl(ctp), sl(xp)  # (B,C,...)
        a_bar = jnp.exp(
            dtc.astype(jnp.float32)[..., None] * (-a.astype(jnp.float32))[None, None]
        )  # (B,C,di,st)
        bx = (
            dtc.astype(jnp.float32)[..., None]
            * btc.astype(jnp.float32)[:, :, None, :]
            * xc.astype(jnp.float32)[..., None]
        )  # (B,C,di,st)
        # pin batch to data and d_inner to model — without this GSPMD
        # replicates the scan tensors over 'data' under fsdp (34 GiB each
        # on the 398B config)
        a_bar = constrain(a_bar, "b.m.")
        bx = constrain(bx, "b.m.")
        h_all, h_last = _ssm_chunk_scan(
            a_bar.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3), h
        )
        h_all = constrain(h_all, ".bm.")
        y = jnp.einsum("cbds,bcs->bcd", h_all, ctc.astype(jnp.float32))
        return constrain(h_last, "bm."), constrain(y, "b.m")

    body = jax.checkpoint(chunk_body) if chunk_remat else chunk_body
    h_last, ys = lax.scan(
        body, constrain(h0.astype(jnp.float32), "bm."), jnp.arange(nchunks)
    )
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, nchunks * chunk, di)[:, :s]
    return y, h_last


def apply_mamba(
    p: Params,
    x: jax.Array,  # (B,S,D)
    cfg: ModelConfig,
    state: dict | None = None,  # decode: {"conv": (B,K-1,di), "ssm": (B,di,st)}
):
    """Returns (out (B,S,D), new_state or None)."""
    di, st, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    bsz, s, _ = x.shape
    xz = layers.apply_linear(p["in_proj"], x)
    xin, z = jnp.split(xz, 2, axis=-1)  # (B,S,di) each
    xin, z = constrain(xin, "b.m"), constrain(z, "b.m")

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    proj = layers.apply_linear(p["x_proj"], xc)  # (B,S,dr+2st)
    dt_lowrank, b_t, c_t = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = layers.apply_linear(p["dt_proj"], dt_lowrank) + p["dt_bias_init"].astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32)).astype(x.dtype)  # (B,S,di)

    a = jnp.exp(p["A_log"].astype(jnp.float32))  # (di,st), positive → A = −a
    h0 = (
        state["ssm"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((bsz, di, st), jnp.float32)
    )

    if s == 1 and state is not None:
        # O(1) decode step
        a_bar = jnp.exp(dt.astype(jnp.float32)[..., 0, :, None] * (-a)[None])  # (B,di,st)
        bx = (
            dt.astype(jnp.float32)[:, 0, :, None]
            * b_t.astype(jnp.float32)[:, 0, None, :]
            * xc.astype(jnp.float32)[:, 0, :, None]
        )
        h = a_bar * h0 + bx
        y = jnp.einsum("bds,bs->bd", h, c_t.astype(jnp.float32)[:, 0])[:, None, :]
        h_last = h
    else:
        y, h_last = ssm_scan(
            dt, a, b_t, c_t, xc, h0, chunk_remat=cfg.ssm_chunk_remat
        )

    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    out = layers.apply_linear(p["out_proj"], y)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_last.astype(state["ssm"].dtype)}
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), dtype),
    }
