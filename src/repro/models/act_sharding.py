"""Activation-sharding constraints for model internals.

GSPMD propagation from parameter/batch shardings is usually enough, but under
fsdp the weight contractions make it profitable-looking for XLA to replicate
activations over the ``data`` axis inside the mamba/attention scans — on the
398B config that materialized ~34 GiB f32 scan tensors (batch unsharded).
These helpers pin the batch dim of key activations.

The context records which mesh axes are *available* (GSPMD-auto, visible to
``with_sharding_constraint``). Inside a ``shard_map`` the manual axes must not
be referenced, so the step builders set the context accordingly.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _current_mesh():
    """The ambient mesh: ``jax.sharding.get_abstract_mesh`` on jax ≥ 0.5,
    else the 0.4.x resource-env physical mesh (set by ``use_mesh``)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def _ctx():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_sharding(batch_axes: tuple[str, ...] | None, model_axis: str | None):
    prev = _ctx()
    _state.ctx = {"batch": batch_axes or None, "model": model_axis}
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array, dims: str) -> jax.Array:
    """Constrain by a dim-role string: 'b'=batch, 'm'=model-sharded, '.'=open.

    e.g. residual (B,S,D) → 'b..'; mamba scan elem (C,B,di,st) → '.bm.'.
    No-op outside an activation_sharding context.
    """
    ctx = _ctx()
    if ctx is None or (ctx["batch"] is None and ctx["model"] is None):
        return x
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x  # no mesh in context (single-device paths)
    spec = []
    for i, role in enumerate(dims):
        if role == "b" and ctx["batch"] and x.shape[i] % _axes_size(ctx["batch"]) == 0:
            spec.append(ctx["batch"])
        elif role == "m" and ctx["model"] and x.shape[i] % _axes_size((ctx["model"],)) == 0:
            spec.append(ctx["model"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _axes_size(axes) -> int:
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return 1 << 30  # no mesh → make divisibility fail → no constraint
    n = 1
    for a in axes:
        if a not in mesh.shape:
            return 1 << 30
        n *= mesh.shape[a]
    return n
