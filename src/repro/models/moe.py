"""Mixture-of-experts block: top-k router + capacity-based dispatch/combine.

The dispatch/combine are expressed as einsums against a one-hot dispatch
tensor (the standard GSPMD-MoE formulation) so that sharding the expert axis
over the ``model`` mesh axis turns the dispatch into an all-to-all — the
communication pattern the roofline tracks for the MoE architectures.

Aux losses: switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.act_sharding import constrain
from repro.models.config import ModelConfig


def init_moe(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": layers.init_linear(ks[0], d, e, dtype),
        "w_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * scale_out).astype(dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) * scale_in).astype(dtype)
    return p


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(cap, cfg.experts_per_token)


MOE_GROUP = 512  # dispatch group size: the (tokens × E × C) one-hot tensor
# scales as 1.25·k·G per token, so grouping caps activation memory (standard
# GSPMD-MoE practice) and sets the all-to-all granularity.


def apply_moe(p, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) → (out, aux). Tokens are dispatched within groups of
    MOE_GROUP to bound the one-hot dispatch tensor."""
    b_orig, s_orig, d = x.shape
    g = min(MOE_GROUP, s_orig)
    if s_orig % g == 0 and s_orig > g:
        x = x.reshape(b_orig * (s_orig // g), g, d)
    out, aux = _apply_moe_grouped(p, x, cfg)
    return out.reshape(b_orig, s_orig, d), aux


def _apply_moe_grouped(p, x: jax.Array, cfg: ModelConfig):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = s  # capacity is per-group
    cap = _capacity(cfg, tokens)

    logits = layers.apply_linear(p["router"], x).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    flat = onehot.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)
    pos_in_expert = jnp.sum(pos_in_expert * onehot, axis=-1)  # (B,S,k)
    keep = pos_in_expert < cap

    cdtype = x.dtype
    cap_onehot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), cap, dtype=cdtype)
    cap_onehot = cap_onehot * keep[..., None].astype(cdtype)
    # dispatch: (B,S,E,C) — built in compute dtype to halve the big one-hots
    dispatch = jnp.einsum("bske,bskc->bsec", onehot.astype(cdtype), cap_onehot)
    combine = jnp.einsum(
        "bsk,bske,bskc->bsec", gate_vals.astype(cdtype), onehot.astype(cdtype), cap_onehot
    )

    expert_in = jnp.einsum("bsd,bsec->becd", x, dispatch.astype(cdtype))  # (B,E,C,D)
    expert_in = constrain(expert_in, "bm..")
    h = jnp.einsum("becd,edf->becf", expert_in, p["w_in"].astype(cdtype))
    if cfg.gated_mlp:
        g = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"].astype(cdtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "bm..")
    expert_out = constrain(
        jnp.einsum("becf,efd->becd", h, p["w_out"].astype(cdtype)), "bm.."
    )
    out = jnp.einsum("becd,bsec->bsd", expert_out, combine.astype(cdtype))

    # switch load-balance loss: E · Σ_e fraction_tokens_e · mean_prob_e
    frac = jnp.mean(onehot[..., 0, :], axis=1) if k == 1 else jnp.mean(
        jnp.sum(onehot, axis=2) / k, axis=1
    )  # (B,E)
    mean_prob = jnp.mean(probs, axis=1)  # (B,E)
    aux = e * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, {"moe_aux_loss": aux, "moe_z_loss": zloss}
