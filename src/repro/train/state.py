"""Training state pytree and constructors."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregation, optim
from repro.models import transformer
from repro.models.config import ModelConfig


class TrainState(NamedTuple):
    params: Any
    opt_state: Any  # worker-local transform state (momentum etc.)
    agg_state: aggregation.AggState
    step: jax.Array


def ef_world(mesh, ef_axes: tuple[str, ...]) -> int:
    w = 1
    for a in ef_axes:
        w *= mesh.shape[a]
    return w


def _broadcast_worker_state(tree, w: int):
    """Give per-worker state a leading EF-world axis (stacked across workers)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (w,) + x.shape), tree)


def init_train_state(
    cfg: ModelConfig,
    key,
    local_chain: optim.Transform,
    strategy: str,
    mesh=None,
    ef_axes: tuple[str, ...] = (),
    error_dtype=jnp.float32,
    bucket_size: int | None = None,
) -> TrainState:
    """``bucket_size`` must match the value later passed to
    ``make_train_step`` — it selects bucketed (repro.comm) vs per-leaf EF
    residual layout.

    The overlap schedule deliberately does NOT appear here: EF residuals are
    keyed by (strategy, bucket_size) only, and the overlapped executor reads/
    writes the same ``(n_buckets, bucket_size)`` stacks as the one-shot path
    — so ``--overlap`` / ``--overlap-groups`` can change across restarts
    without invalidating checkpoints or perturbing the trajectory."""
    params = transformer.init_params(cfg, key)
    opt_state = local_chain.init(params)
    w = ef_world(mesh, ef_axes) if mesh is not None and ef_axes else 1
    agg = aggregation.init_agg_state(
        strategy, params, world=w, error_dtype=error_dtype, bucket_size=bucket_size
    )
    if ef_axes:
        agg = agg._replace(
            worker_error=_broadcast_worker_state(agg.worker_error, w),
            server_error=_broadcast_worker_state(agg.server_error, w),
        )
        # momentum traces are also worker-local when EF axes are manual
        opt_state = _broadcast_worker_state(opt_state, w)
    return TrainState(params=params, opt_state=opt_state, agg_state=agg, step=jnp.int32(0))


def abstract_train_state(
    cfg, key, local_chain, strategy, mesh, ef_axes, error_dtype=jnp.float32,
    bucket_size: int | None = None,
):
    """eval_shape'd TrainState for dry-run lowering (no allocation)."""
    return jax.eval_shape(
        lambda k: init_train_state(
            cfg, k, local_chain, strategy, mesh, ef_axes, error_dtype, bucket_size
        ),
        key,
    )
