"""Checkpointing: pure-numpy npz + JSON manifest (orbax is not offline).

State pytrees are flattened with '/'-joined key paths; restore rebuilds into
the caller-provided abstract structure (so shardings/dtypes are re-applied by
the caller via device_put).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(directory: str, state: Any, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = {}
    jax.tree_util.tree_map_with_path(
        lambda p, x: flat.setdefault(_path_str(p), np.asarray(x)), state
    )
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    latest = os.path.join(directory, "LATEST")
    with open(latest, "w") as f:
        f.write(str(step))
    return path


def latest_step(directory: str) -> int | None:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(directory: str, like: Any, step: int | None = None) -> Any:
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))

    def fill(path, x):
        arr = data[_path_str(path)]
        assert tuple(arr.shape) == tuple(x.shape), (path, arr.shape, x.shape)
        return jnp.asarray(arr, dtype=x.dtype)

    return jax.tree_util.tree_map_with_path(fill, like)
