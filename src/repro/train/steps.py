"""Distributed train / prefill / decode step builders.

Three gradient-exchange paths share the loss code (DESIGN.md §5):

``dense``
    one ``jax.jit``; GSPMD inserts the fp32 gradient all-reduce/reduce-scatter
    — the SGD communication baseline. (An EF *optimizer* may still be used —
    that is the paper's single-worker Algorithm 2 applied per param shard.)

Bucketed EF strategies (the default wire path, ``bucket_size`` set)
    Per-worker grads come from a ``vmap`` over an explicit leading EF-worker
    axis (batch reshaped ``(W, B/W, ...)``) inside the ordinary GSPMD-auto
    world — no ``shard_map`` around the model, so tensor/expert/fsdp
    parallelism, remat, and the layer-stack ``lax.scan`` all compose
    untouched. Updates are flattened into fixed-size buckets
    (:mod:`repro.comm.bucketize`) and exchanged by the fully-manual
    collective in :mod:`repro.comm.collective` — the only ``shard_map`` in
    the step, with every mesh axis manual, which is what keeps jaxlib
    0.4.x's partial-manual ``IsManualSubgroup`` abort unreachable.

Per-leaf EF strategies (``bucket_size=None`` fallback)
    The original ``shard_map``-around-the-model path: manual over the EF
    worker axes with every other mesh axis GSPMD-auto, compressing leaf by
    leaf (:mod:`repro.core.aggregation`). Preserves intra-leaf shardings (no
    flatten), so it remains the choice for the giant-model dry-run — but the
    partial-manual configuration aborts on jaxlib 0.4.x.

Worker-local state (EF residuals, momentum traces) is stacked on a leading
EF-world axis and sharded over the EF axes; see ``state_specs``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import adversary as comm_adversary
from repro.comm import api as comm_api
from repro.comm import bucketize as comm_bucketize
from repro.comm import collective as comm_collective
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core import aggregation, optim
from repro.core.compressors import Compressor
from repro.models import layers, transformer
from repro.obs import trace as obs_trace
from repro.obs import telemetry as obs_telemetry
from repro.utils import compat
from repro.models.act_sharding import activation_sharding
from repro.models.config import ModelConfig
from repro.sharding.rules import ShardingRules
from repro.train.state import TrainState


def _prepend(spec: P, *axes) -> P:
    return P(*axes, *tuple(spec))


def _filter_manual_spec(spec: P, manual: frozenset) -> P:
    """shard_map in/out_specs may only mention manual axes; auto-axis
    shardings ride along implicitly. Drop non-manual names from the spec."""
    out = []
    for entry in tuple(spec):
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in manual else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _filter_manual(tree_specs, manual):
    manual = frozenset(manual)
    return jax.tree.map(
        lambda s: _filter_manual_spec(s, manual), tree_specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def _worker_state_specs(tree_specs, ef_axes):
    """Worker-local pytrees get a leading EF-world dim sharded over ef_axes."""
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]
    return jax.tree.map(lambda s: _prepend(s, ef), tree_specs)


class StepBundle:
    """A compiled-step description: fn + in/out shardings, ready to lower."""

    def __init__(self, fn, in_shardings, out_shardings, donate_argnums=()):
        self.fn = fn
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.donate_argnums = donate_argnums

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def _make_grad_fn(cfg: ModelConfig, microbatches: int, act_ctx):
    """value_and_grad of the mean loss, optionally accumulated over
    microbatches (batch dim split M-ways, lax.scan accumulation — constant
    activation memory at the cost of M sequential passes)."""

    def single(params, batch):
        def lf(p):
            with act_ctx():
                return transformer.loss_fn(p, cfg, batch)

        return jax.value_and_grad(lf, has_aux=True)(params)

    if microbatches <= 1:
        return single

    def accumulated(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, mb_batch):
            (loss, metrics), grads = single(params, mb_batch)
            acc_g, acc_l, acc_m = carry
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            acc_m = {k: acc_m[k] + metrics[k] for k in acc_m}
            return (acc_g, acc_l + loss, acc_m), None

        zeros_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        # first microbatch runs unrolled to seed the metric structure
        (l0, m0), g0 = single(params, jax.tree.map(lambda x: x[0], mb))
        zero_m = {k: jnp.zeros_like(v) for k, v in m0.items()}
        (grads, loss, metrics), _ = jax.lax.scan(
            body, (zeros_g, jnp.float32(0.0), zero_m), jax.tree.map(lambda x: x[1:], mb)
        )
        grads = jax.tree.map(lambda a, g: (a + g.astype(jnp.float32)) / microbatches, grads, g0)
        loss = (loss + l0) / microbatches
        metrics = {k: (metrics[k] + m0[k]) / microbatches for k in metrics}
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return (loss, metrics), grads

    return accumulated


def stageable(cfg: ModelConfig, microbatches: int) -> bool:
    """True when the loss decomposes into embed | block-stack | head ``vjp``
    stages. The block stack itself is a ``lax.scan``, so per-LAYER grads are
    never splittable here — three stages is the finest checkpoint-boundary
    chunking this model family admits; models that fail even this gate fall
    back to post-hoc pipelining of compress/collective (the overlap executor
    works either way)."""
    return microbatches <= 1 and not cfg.encoder_layers and not cfg.num_patch_tokens


def _make_staged_grad_fn(cfg: ModelConfig, act_ctx):
    """value_and_grad chunked at the embed | stack | head reverse-AD
    boundaries via per-stage ``jax.vjp``.

    Numerically this is the same chain rule over the same primitives as
    ``jax.value_and_grad`` of the fused loss (tests pin bitwise equality);
    what changes is the *dependency structure* of the jit graph: head and
    final-norm gradients are produced by ``vjp_head`` before the stack's
    backward scan runs, and the embedding gradient only at the very end — so
    the overlap executor's first bucket groups (rank 0 = head/final-norm, see
    :mod:`repro.overlap.schedule`) can compress and issue their collectives
    while the backward is still inside the scan.
    """
    tied = cfg.tie_embeddings

    def staged(params, batch):
        p_embed = params["embed"]
        p_head = {"final_norm": params["final_norm"]}
        if not tied:
            p_head["head"] = params["head"]

        def f_embed(pe):
            with act_ctx():
                x, _ = transformer.embed_inputs({"embed": pe}, cfg, batch)
            return x

        def f_stack(pb, x):
            with act_ctx():
                positions = 0 + jnp.arange(x.shape[1])
                x1, _, aux = transformer._run_stack(pb, cfg, x, positions, None, 0, None)
            return x1, aux

        def f_head(ph, pe, x1):
            with act_ctx():
                x = layers.apply_norm(ph["final_norm"], x1, cfg.norm_type)
                if tied:
                    logits = x @ pe["table"].astype(x.dtype).T
                else:
                    logits = layers.apply_linear(ph["head"], x)
                logits = logits.astype(jnp.float32)
                logp = jax.nn.log_softmax(logits, axis=-1)
                labels = batch["labels"]
                nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
                mask = batch.get("loss_mask", jnp.ones_like(nll))
                return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

        x0, vjp_embed = jax.vjp(f_embed, p_embed)
        (x1, aux), vjp_stack = jax.vjp(f_stack, params["blocks"], x0)
        ce, vjp_head = jax.vjp(f_head, p_head, p_embed, x1)
        total = ce
        if cfg.is_moe:
            total = (
                total
                + cfg.aux_loss_coef * aux["moe_aux_loss"]
                + cfg.router_z_coef * aux["moe_z_loss"]
            )

        # reverse-AD in stage order: head grads first, embedding last
        g_head, g_embed_head, dx1 = vjp_head(jnp.ones_like(ce))
        daux = {
            "moe_aux_loss": jnp.float32(cfg.aux_loss_coef if cfg.is_moe else 0.0),
            "moe_z_loss": jnp.float32(cfg.router_z_coef if cfg.is_moe else 0.0),
        }
        g_blocks, dx0 = vjp_stack((dx1, daux))
        (g_embed,) = vjp_embed(dx0)
        if tied:  # the head's contribution to the shared table accumulates
            g_embed = jax.tree.map(jnp.add, g_embed, g_embed_head)

        grads = {"blocks": g_blocks, "embed": g_embed, "final_norm": g_head["final_norm"]}
        if not tied:
            grads["head"] = g_head["head"]
        metrics = {"loss": ce, **aux}
        return (total, metrics), grads

    return staged


def make_train_step(
    cfg: ModelConfig,
    mesh,
    rules: ShardingRules,
    *,
    spec: comm_api.CommSpec | None = None,
    strategy: str = "dense",
    comp: Compressor | None = None,
    local_chain: optim.Transform,
    ef_axes: tuple[str, ...] = (),
    batch_example: Any,
    state_example: TrainState,
    microbatches: int = 1,
    bucket_size: int | None = None,
    overlap_groups: int | None = None,
    byz: ByzConfig | None = None,
) -> StepBundle:
    """Build the train step for one :class:`~repro.comm.api.CommSpec`.

    ``spec`` is the one description of the gradient exchange (strategy,
    compressor, bucket size, collective backend, overlap/byz riders); the
    individual keyword knobs remain accepted as the legacy spelling and are
    folded into a spec when ``spec`` is not given (``spec`` wins otherwise).
    All path validation happens in ``CommSpec.validate`` — structural checks
    here, the world-dependent tolerance check at aggregator build time.
    """
    if spec is None:
        spec = comm_api.CommSpec(
            strategy=strategy,
            compressor=comp,
            bucket_size=bucket_size,
            overlap=OverlapConfig(n_groups=overlap_groups) if overlap_groups is not None else None,
            byz=byz,
        )
    spec.validate()
    strategy, comp, bucket_size = spec.strategy, spec.resolved_compressor, spec.bucket_size
    param_specs = rules.param_specs(state_example.params)
    opt_specs_base = jax.tree.map(
        lambda _: P(), state_example.opt_state
    ) if rules.policy == "dp" else _opt_specs(rules, state_example)
    batch_specs = rules.batch_specs(batch_example)

    if strategy == "dense":
        assert not ef_axes

        dp_axes = rules.dp_axes

        grad_fn = _make_grad_fn(
            cfg, microbatches, lambda: activation_sharding(dp_axes, "model")
        )

        def train_step(state: TrainState, batch):
            (loss, metrics), grads = grad_fn(state.params, batch)
            updates, opt_state = local_chain.update(grads, state.opt_state, state.params)
            params = optim.apply_updates(state.params, updates)
            new_state = TrainState(params, opt_state, state.agg_state, state.step + 1)
            d = sum(x.size for x in jax.tree.leaves(grads))
            metrics = dict(metrics, wire_bytes=jnp.float32(8.0 * d), density=jnp.float32(1.0))
            return new_state, (loss, metrics)

        state_specs = TrainState(
            params=param_specs,
            opt_state=opt_specs_base,
            agg_state=jax.tree.map(lambda _: P(), state_example.agg_state),
            step=P(),
        )
        in_sh = (rules.named(state_specs), rules.named(batch_specs))
        out_sh = (rules.named(state_specs), rules.named((P(), {
            k: P() for k in ("loss", "moe_aux_loss", "moe_z_loss", "wire_bytes", "density")
        })))
        return StepBundle(train_step, in_sh, out_sh, donate_argnums=(0,))

    # ---------------- EF strategies: bucketed comm layer (default) --------
    assert ef_axes, "EF strategies need at least one manual worker axis"
    if bucket_size is not None:
        return _make_bucketed_ef_step(
            cfg, mesh, rules, spec=spec, local_chain=local_chain,
            ef_axes=ef_axes, batch_example=batch_example, state_example=state_example,
            microbatches=microbatches,
            param_specs=param_specs, opt_specs_base=opt_specs_base,
            batch_specs=batch_specs,
        )

    # ---------------- per-leaf fallback: shard_map over the EF worker axes
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]

    has_worker_err = bool(jax.tree.leaves(state_example.agg_state.worker_error))
    agg_specs = aggregation.AggState(
        worker_error=_worker_state_specs(param_specs, ef_axes) if has_worker_err else (),
        server_error=jax.tree.map(lambda _: P(ef), state_example.agg_state.server_error),
        key=P(),
        steps=P(),
    )
    opt_specs = _worker_state_specs(opt_specs_base, ef_axes)
    state_specs = TrainState(params=param_specs, opt_state=opt_specs, agg_state=agg_specs, step=P())
    metric_keys = ("loss", "moe_aux_loss", "moe_z_loss", "wire_bytes", "density")

    def _strip(tree):  # drop the local leading EF-world dim (size 1)
        return jax.tree.map(lambda x: x[0], tree)

    def _lift(tree):
        return jax.tree.map(lambda x: x[None], tree)

    auto_dp = tuple(a for a in rules.dp_axes if a not in ef_axes)
    grad_fn = _make_grad_fn(
        cfg, microbatches, lambda: activation_sharding(auto_dp or None, "model")
    )

    def worker_body(params, batch, opt_state, agg_state):
        (loss, metrics), grads = grad_fn(params, batch)
        opt_local = _strip(opt_state)
        agg_local = agg_state._replace(
            worker_error=_strip(agg_state.worker_error),
            server_error=_strip(agg_state.server_error),
        )
        updates, opt_local = local_chain.update(grads, opt_local, params)
        updates, agg_local, info = aggregation.aggregate(
            strategy, updates, agg_local, ef_axes, comp
        )
        loss = lax.pmean(loss, ef_axes)
        metrics = {k: lax.pmean(v, ef_axes) for k, v in metrics.items()}
        metrics["wire_bytes"] = info.wire_bytes_per_device
        metrics["density"] = info.mean_density
        new_agg = agg_state._replace(
            worker_error=_lift(agg_local.worker_error),
            server_error=_lift(agg_local.server_error),
            key=agg_local.key,
            steps=agg_local.steps,
        )
        return updates, _lift(opt_local), new_agg, loss, metrics

    manual = frozenset(ef_axes)
    sharded_body = compat.shard_map(
        worker_body,
        mesh=mesh,
        in_specs=_filter_manual((param_specs, batch_specs, opt_specs, agg_specs), manual),
        out_specs=_filter_manual(
            (param_specs, opt_specs, agg_specs, P(), {k: P() for k in metric_keys}),
            manual,
        ),
        manual_axes=manual,
    )

    def train_step(state: TrainState, batch):
        updates, opt_state, agg_state, loss, metrics = sharded_body(
            state.params, batch, state.opt_state, state.agg_state
        )
        params = optim.apply_updates(state.params, updates)
        new_state = TrainState(params, opt_state, agg_state, state.step + 1)
        return new_state, (loss, metrics)

    in_sh = (rules.named(state_specs), rules.named(batch_specs))
    out_sh = (rules.named(state_specs), rules.named((P(), {k: P() for k in metric_keys})))
    return StepBundle(train_step, in_sh, out_sh, donate_argnums=(0,))


def _make_bucketed_ef_step(
    cfg: ModelConfig,
    mesh,
    rules: ShardingRules,
    *,
    spec: comm_api.CommSpec,
    local_chain: optim.Transform,
    ef_axes: tuple[str, ...],
    batch_example: Any,
    state_example: TrainState,
    microbatches: int,
    param_specs,
    opt_specs_base,
    batch_specs,
) -> StepBundle:
    """EF train step through the bucketed comm layer (see module docstring).

    The aggregator comes from the one construction path,
    :func:`repro.comm.api.make_aggregator`: it validates ``spec`` against the
    mesh, resolves the collective backend, and — with ``spec.overlap`` set —
    builds the overlap pipeline (a static
    :class:`~repro.overlap.schedule.OverlapSchedule` groups the buckets by
    reverse-AD availability and per-group collectives issue as independent
    dataflow chains). When the model admits it, the overlapped grad fn is the
    staged-``vjp`` variant so the head-stage groups' collectives are
    data-ready before the backward scan finishes. The trajectory is bitwise
    identical to the one-shot step.
    """
    strategy, comp, byz = spec.strategy, spec.resolved_compressor, spec.byz
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]
    w = comm_collective.world_size(mesh, ef_axes)
    layout = comm_bucketize.build_layout(state_example.params, spec.bucket_size)
    # a 1-worker world has no collective latency to hide — pipelining would
    # be pure dispatch overhead, so make_aggregator degenerates overlap to
    # the one-shot path there
    overlap = spec.overlap is not None and w > 1
    agg_fn = comm_api.make_aggregator(
        spec, layout, mesh, ef_axes, params=state_example.params
    )
    attackers = comm_adversary.n_attackers(byz.fraction, w) if byz is not None else 0

    auto_dp = tuple(a for a in rules.dp_axes if a not in ef_axes)
    act_ctx = lambda: activation_sharding(auto_dp or None, "model")
    if overlap and stageable(cfg, microbatches):
        grad_fn = _make_staged_grad_fn(cfg, act_ctx)
    else:
        grad_fn = _make_grad_fn(cfg, microbatches, act_ctx)

    def _split_workers(x):
        b = x.shape[0]
        assert b % w == 0, f"batch dim {b} not divisible by EF world {w}"
        return x.reshape(w, b // w, *x.shape[1:])

    auto_dp_size = comm_collective.world_size(mesh, auto_dp)

    def _worker_sharding(leaf):
        inner = auto_dp if (auto_dp and leaf.shape[1] % auto_dp_size == 0) else None
        return NamedSharding(mesh, P(ef, inner, *([None] * (leaf.ndim - 2))))

    grad_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, _prepend(s, ef)), param_specs,
        is_leaf=lambda s: isinstance(s, P),
    )

    def train_step(state: TrainState, batch):
        wb = jax.tree.map(_split_workers, batch)
        wb = jax.tree.map(
            lambda x: lax.with_sharding_constraint(x, _worker_sharding(x)), wb
        )
        # per-worker grads: vmap over the leading EF-worker axis, params
        # broadcast — pure GSPMD-auto, composes with tp/fsdp/remat/scan
        with obs_trace.span(obs_trace.SPAN_BACKWARD):
            (loss_w, metrics_w), grads_w = jax.vmap(
                lambda b: grad_fn(state.params, b)
            )(wb)
        grads_w = lax.with_sharding_constraint(grads_w, grad_shardings)
        if attackers:
            # fault injection on the worker lanes; the attack key is folded
            # off the carried agg key so the honest RNG stream (split below)
            # is untouched and attackers=0 stays bitwise-identical
            grads_w = comm_adversary.corrupt_worker_tree(
                byz, grads_w, jax.random.fold_in(state.agg_state.key, 0x5A1), world=w
            )
        updates_w, opt_state = jax.vmap(
            lambda g, o: local_chain.update(g, o, state.params)
        )(grads_w, state.opt_state)
        with obs_trace.span(obs_trace.SPAN_BUCKETIZE):
            buckets_w = jax.vmap(lambda u: comm_bucketize.flatten_buckets(layout, u))(
                updates_w
            )
        key, sub = jax.random.split(state.agg_state.key)
        agg_buckets, new_err, new_srv, info = agg_fn(
            buckets_w,
            state.agg_state.worker_error,
            state.agg_state.server_error,
            sub,
        )
        with obs_trace.span(obs_trace.SPAN_APPLY):
            updates = comm_bucketize.unflatten_buckets(layout, agg_buckets)
            params = optim.apply_updates(state.params, updates)
        new_agg = aggregation.AggState(
            worker_error=new_err,
            server_error=new_srv,
            key=key,
            steps=state.agg_state.steps + 1,
        )
        loss = jnp.mean(loss_w)
        metrics = {k: jnp.mean(v) for k, v in metrics_w.items()}
        metrics["wire_bytes"] = info.wire_bytes_per_device
        metrics["density"] = info.mean_density
        if info.telemetry is not None:
            metrics["obs"] = info.telemetry
        new_state = TrainState(params, opt_state, new_agg, state.step + 1)
        return new_state, (loss, metrics)

    agg_specs = aggregation.AggState(
        worker_error=jax.tree.map(lambda _: P(ef), state_example.agg_state.worker_error),
        server_error=jax.tree.map(lambda _: P(ef), state_example.agg_state.server_error),
        key=P(),
        steps=P(),
    )
    opt_specs = _worker_state_specs(opt_specs_base, ef_axes)
    state_specs = TrainState(
        params=param_specs, opt_state=opt_specs, agg_state=agg_specs, step=P()
    )
    metric_keys = ("loss", "moe_aux_loss", "moe_z_loss", "wire_bytes", "density")
    metrics_sp = {k: P() for k in metric_keys}
    if spec.telemetry != "off":
        metrics_sp["obs"] = obs_telemetry.replicated_specs()
    in_sh = (rules.named(state_specs), rules.named(batch_specs))
    out_sh = (rules.named(state_specs), rules.named((P(), metrics_sp)))
    return StepBundle(train_step, in_sh, out_sh, donate_argnums=(0,))


def _opt_specs(rules: ShardingRules, state_example: TrainState):
    """Momentum traces etc. mirror param sharding; scalar states replicated."""
    param_specs = rules.param_specs(state_example.params)

    def rule(path, leaf):
        # TraceState/AdamState leaves mirror params by shape; counters scalar
        if leaf.ndim == 0:
            return P()
        # find a param leaf with identical path suffix via shape match
        return _match_param_spec(leaf, param_specs, state_example.params)

    return jax.tree_util.tree_map_with_path(rule, state_example.opt_state)


def _match_param_spec(leaf, param_specs, params):
    specs = jax.tree.leaves(param_specs, is_leaf=lambda s: isinstance(s, P))
    shapes = [p.shape for p in jax.tree.leaves(params)]
    for sp, sh in zip(specs, shapes):
        if sh == leaf.shape:
            return sp
    return P()


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh, rules: ShardingRules, *, batch_example, cache_example, params_example) -> StepBundle:
    param_specs = rules.param_specs(params_example)
    batch_specs = rules.batch_specs(batch_example)
    cache_specs = rules.cache_specs(cache_example)

    def prefill(params, batch, cache):
        with activation_sharding(rules.dp_axes, "model"):
            logits, cache, _ = transformer.forward(params, cfg, batch, cache=cache, pos=0)
        return logits[:, -1:, :], cache

    logit_spec = P(tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None)
    in_sh = (rules.named(param_specs), rules.named(batch_specs), rules.named(cache_specs))
    out_sh = (NamedSharding(mesh, logit_spec), rules.named(cache_specs))
    return StepBundle(prefill, in_sh, out_sh, donate_argnums=(2,))


def make_decode_step(cfg: ModelConfig, mesh, rules: ShardingRules, *, cache_example, params_example) -> StepBundle:
    param_specs = rules.param_specs(params_example)
    cache_specs = rules.cache_specs(cache_example)
    b = jax.tree.leaves(cache_example)[0].shape[1]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = P(dp) if b % dp_size == 0 and dp_size > 1 else P()

    def decode(params, cache, tokens, pos):
        with activation_sharding(rules.dp_axes, "model"):
            return transformer.decode_step(params, cfg, cache, tokens, pos)

    in_sh = (
        rules.named(param_specs),
        rules.named(cache_specs),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    out_sh = (NamedSharding(mesh, tok_spec), rules.named(cache_specs))
    return StepBundle(decode, in_sh, out_sh, donate_argnums=(1,))
