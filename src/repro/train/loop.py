"""Training loop: builds the step bundle, streams batches, logs, checkpoints."""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Iterator

import jax

from repro.comm import bucketize as comm_bucketize
from repro.comm import collective as comm_collective
from repro.comm.api import CommSpec
from repro.comm.bucketize import DEFAULT_BUCKET_SIZE
from repro.obs import sink as obs_sink
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core import optim
from repro.core.compressors import get_compressor
from repro.data import synthetic
from repro.launch.mesh import ef_axis_names, use_mesh
from repro.models.config import ModelConfig
from repro.sharding.rules import ShardingRules, default_policy
from repro.train import checkpoint as ckpt
from repro.train import steps as steps_lib
from repro.train.state import init_train_state


@dataclasses.dataclass
class TrainJob:
    cfg: ModelConfig
    mesh: Any
    steps: int = 100
    batch: int = 8
    seq: int = 128
    lr: float = 0.02
    momentum: float = 0.0
    weight_decay: float = 0.0
    optimizer: str = "sgd"  # local per-worker chain: sgd | ef_sgd | adam | ...
    # dense | ef_allgather | ef_ring | ef_alltoall | majority_vote |
    # ef_coord_median | ef_trimmed_mean | ef_norm_filter
    strategy: str = "dense"
    compressor: str = "scaled_sign"
    policy: str | None = None
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = ""
    lr_schedule: str = "step_decay"  # the paper's /10-decimation schedule
    microbatches: int = 1  # gradient accumulation (M sequential passes)
    # gradient-exchange granularity: fixed-size buckets through repro.comm
    # (the default wire path); None falls back to per-leaf aggregation
    bucket_size: int | None = DEFAULT_BUCKET_SIZE
    # async overlap: pipeline per-group compression + collectives with the
    # backward (repro.overlap); None = one aggregator call after full grad
    overlap: OverlapConfig | None = None
    # Byzantine knobs: fault-injected worker lanes + declared robust
    # tolerance (repro.comm.adversary / repro.comm.robust); None = honest
    byz: ByzConfig | None = None
    # federated rider (repro.fed): run rounds over a simulated client
    # population instead of data-parallel steps; steps count ROUNDS and
    # batch is the PER-CLIENT batch (see repro.fed.loop)
    fed: Any = None  # FedSpec | None
    # the one spec describing the whole gradient exchange; None folds the
    # individual legacy fields above into a CommSpec (comm_spec()), set it
    # to override them wholesale (e.g. to pick a collective backend)
    comm: CommSpec | None = None
    # in-graph telemetry level ("off" | "full") — repro.obs run records
    telemetry: str = "off"
    # directory for the schema-versioned run.jsonl (repro.obs.sink); empty
    # disables the file sink (log_fn / history still work as before)
    log_dir: str = ""

    def comm_spec(self) -> CommSpec:
        """The job's gradient-exchange spec (``comm`` or the legacy fields)."""
        if self.comm is not None:
            if self.telemetry != "off" and self.comm.telemetry == "off":
                return dataclasses.replace(self.comm, telemetry=self.telemetry)
            return self.comm
        return CommSpec(
            strategy=self.strategy,
            compressor=self.compressor,
            bucket_size=self.bucket_size,
            overlap=self.overlap,
            byz=self.byz,
            telemetry=self.telemetry,
            fed=self.fed,
        )


def _local_chain(job: TrainJob) -> optim.Transform:
    sched = {
        "constant": optim.constant_schedule(job.lr),
        "step_decay": optim.step_decay_schedule(job.lr, job.steps),
        "cosine": optim.cosine_schedule(job.lr, job.steps),
    }[job.lr_schedule]
    kw = dict(weight_decay=job.weight_decay)
    if job.optimizer in ("sgd", "sgdm"):
        return optim.sgd(sched, momentum=job.momentum or (0.9 if job.optimizer == "sgdm" else 0.0), **kw)
    if job.optimizer in ("ef_sgd", "ef_signsgd"):
        return optim.ef_sgd(sched, compressor=get_compressor(job.compressor), momentum=job.momentum, **kw)
    if job.optimizer == "signsgd":
        return optim.signsgd(sched, **kw)
    if job.optimizer == "signum":
        return optim.signum(sched, **kw)
    if job.optimizer == "adam":
        return optim.adam(sched, **kw)
    raise ValueError(job.optimizer)


def run_training(job: TrainJob, batches: Iterator[dict] | None = None, log_fn: Callable | None = None):
    cfg, mesh = job.cfg, job.mesh
    spec = job.comm_spec()
    if spec.fed is not None:
        from repro.fed import loop as fed_loop  # lazy: keeps fed out of DP runs

        return fed_loop.run_fed_training(job, spec, log_fn=log_fn)
    policy = job.policy or default_policy(cfg)
    rules = ShardingRules(cfg, mesh, policy)
    ef_axes = ef_axis_names(mesh, policy) if spec.strategy != "dense" else ()
    chain = _local_chain(job)
    key = jax.random.PRNGKey(job.seed)

    if batches is None:
        batches = synthetic.token_batches(job.seed, job.batch, job.seq, cfg.vocab_size)

    bucket_size = spec.bucket_size if spec.strategy != "dense" else None
    with use_mesh(mesh):
        state = init_train_state(
            cfg, key, chain, spec.strategy, mesh, ef_axes, bucket_size=bucket_size
        )
        example = next(batches)
        bundle = steps_lib.make_train_step(
            cfg, mesh, rules,
            spec=spec, local_chain=chain, ef_axes=ef_axes,
            batch_example=example, state_example=state, microbatches=job.microbatches,
        )
        state = jax.device_put(state, bundle.in_shardings[0])
        step_fn = bundle.jit()

        writer = None
        if job.log_dir:
            writer = obs_sink.RunRecordWriter(os.path.join(job.log_dir, "run.jsonl"))
            modeled = None
            if spec.strategy != "dense" and spec.bucket_size is not None:
                layout = comm_bucketize.build_layout(state.params, spec.bucket_size)
                w = comm_collective.world_size(mesh, ef_axes)
                modeled = obs_telemetry.modeled_wire_bytes(
                    spec.strategy, layout, w, spec.resolved_compressor
                )
            writer.write(
                obs_sink.run_meta(
                    config={
                        "strategy": spec.strategy,
                        "backend": spec.backend,
                        "steps": job.steps,
                        "batch": job.batch,
                        "seq": job.seq,
                        "optimizer": job.optimizer,
                        "policy": policy,
                        "bucket_size": spec.bucket_size,
                    },
                    telemetry=spec.telemetry,
                    modeled_wire_bytes=modeled,
                )
            )

        history = []
        timers = obs_trace.WallTimers()
        t0 = time.time()
        try:
            for i in range(job.steps):
                batch = example if i == 0 else next(batches)
                batch = jax.device_put(batch, bundle.in_shardings[1])
                logged = i % job.log_every == 0 or i == job.steps - 1
                with obs_trace.step_span(i), timers.region("step"):
                    state, (loss, metrics) = step_fn(state, batch)
                    if logged:
                        jax.block_until_ready(loss)
                walls = timers.drain()
                if logged:
                    rec = obs_sink.step_record(i, {"loss": loss, **metrics}, walls=walls)
                    rec["wall_s"] = time.time() - t0
                    history.append(rec)
                    if log_fn:
                        log_fn(rec)
                    if writer:
                        writer.write(rec)
                if job.ckpt_every and job.ckpt_dir and (i + 1) % job.ckpt_every == 0:
                    ckpt.save_checkpoint(job.ckpt_dir, jax.device_get(state), i + 1)
        finally:
            # the epilogue record is unconditional — a zero-step run (or a
            # crashed one) still closes with a parseable "final" line
            if writer:
                writer.write(
                    obs_sink.final_record(history, steps=job.steps, wall_s=time.time() - t0)
                )
                writer.close()
        return state, history
