"""Byzantine-robust combination of per-worker bucket payloads.

The robust strategies (``ef_coord_median``, ``ef_trimmed_mean``,
``ef_norm_filter``) reuse the EF payload exchange wholesale: every worker
runs the same per-bucket EF compression, payloads ride the same slot-native
backend exchange (all-gather, ppermute ring, or remote-DMA ring — the
estimators are backend-agnostic), and the wire bill is identical —
robustness is purely a *decode-side* change. Instead of the two-buffer
running mean of ``compressed.decode_mean_buckets``, the combiner reads the
exchange's canonical ``(W, n_buckets, bucket_size)`` slot stack of
per-worker reconstructions and applies an order-statistics estimator over
the worker axis (Ghosh et al., arXiv:1911.09721 — error feedback composes
with robust aggregation):

``ef_coord_median``
    coordinate-wise median (even W: mean of the two middle order
    statistics). Tolerates up to ``(W-1)//2`` adversaries per coordinate.
``ef_trimmed_mean``
    drop the ``f`` largest and ``f`` smallest values per coordinate, mean
    the surviving ``W - 2f``.
``ef_norm_filter``
    score each worker by L2 distance of its decoded vector to the
    coordinate-wise median, drop the ``f`` farthest, mean the survivors.
    Distance-to-center (not plain norm) is deliberate: a sign-flip adversary
    is norm-preserving, so raw-norm filtering would wave it through.

``byz_f`` is the *declared* adversary budget, a static config — separate
from how many lanes the fault injector (:mod:`repro.comm.adversary`)
actually corrupts; the byz bench measures over- and under-declared budgets.
At ``byz_f == 0`` every strategy short-circuits to the exchange view's mean
reading — the very program the mean strategies trace on that backend — so a
robust strategy in a declared-honest world is bitwise-equal to
``ef_allgather`` / ``ef_ring`` on every transport by construction. The
order-statistics estimators break down at ``2f >= W`` (fewer honest than
adversarial order statistics), which :func:`validate_tolerance` rejects
upfront.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import compressed
from repro.comm.errors import ToleranceError
from repro.core.compressors import Compressor

ROBUST_STRATEGIES = ("ef_coord_median", "ef_trimmed_mean", "ef_norm_filter")


def max_tolerance(world: int) -> int:
    """Largest declarable adversary budget: breakdown needs 2f < W."""
    return max(0, (world - 1) // 2)


def validate_tolerance(strategy: str, byz_f: int, world: int) -> None:
    """Reject strategy/budget combinations that silently degrade.

    Mirrors the upfront ``ef_ring``+``bucket_size=None`` guard: a trimmed
    mean with ``2f >= W`` trims every honest order statistic and a non-robust
    strategy ignores ``byz_f`` entirely — both fail here, at build time,
    naming the valid range.
    """
    if byz_f < 0:
        raise ToleranceError(f"byz_f must be >= 0, got {byz_f}")
    if strategy not in ROBUST_STRATEGIES:
        if byz_f:
            raise ToleranceError(
                f"byz_f={byz_f} only applies to the robust strategies "
                f"{ROBUST_STRATEGIES}; strategy {strategy!r} would silently ignore it"
            )
        return
    if byz_f and 2 * byz_f >= world:
        raise ToleranceError(
            f"{strategy}: declared tolerance byz_f={byz_f} breaks down at "
            f"world={world} (needs 2*byz_f < W); valid range here: "
            f"0 <= byz_f <= {max_tolerance(world)}"
        )


def coord_median(stack: jax.Array) -> jax.Array:
    """Coordinate-wise median over the leading worker axis."""
    w = stack.shape[0]
    s = jnp.sort(stack, axis=0)
    mid = w // 2
    if w % 2:
        return s[mid]
    return 0.5 * (s[mid - 1] + s[mid])


def trimmed_mean(stack: jax.Array, f: int) -> jax.Array:
    """Coordinate-wise mean of the order statistics ``[f : W - f]``."""
    w = stack.shape[0]
    s = jnp.sort(stack, axis=0)
    return jnp.mean(s[f : w - f], axis=0)


def norm_filtered_mean(stack: jax.Array, f: int) -> jax.Array:
    """Mean of the ``W - f`` workers closest (L2) to the coordinate median.

    Ties in the distance scores break deterministically by worker index
    (``argsort`` is stable), so the combine is a pure function of the stack.
    """
    w = stack.shape[0]
    center = coord_median(stack)
    d2 = jnp.sum((stack - center[None]) ** 2, axis=tuple(range(1, stack.ndim)))
    order = jnp.argsort(d2)
    keep = jnp.zeros((w,), jnp.float32).at[order[: w - f]].set(1.0)
    keep = keep.reshape((w,) + (1,) * (stack.ndim - 1))
    return jnp.sum(stack * keep, axis=0) / (w - f)


def combine_stack(strategy: str, stack: jax.Array, byz_f: int) -> jax.Array:
    """Apply one robust estimator to an already-decoded (W, nb, bs) stack.

    The decode-side half of :func:`robust_combine`, split out so a caller
    that needs the stack for other reads (telemetry's per-lane filter
    weights) can decode once and reuse it.
    """
    if strategy == "ef_coord_median":
        return coord_median(stack)
    if strategy == "ef_trimmed_mean":
        return trimmed_mean(stack, byz_f)
    if strategy == "ef_norm_filter":
        return norm_filtered_mean(stack, byz_f)
    raise ValueError(f"unknown robust strategy {strategy!r}; options: {ROBUST_STRATEGIES}")


def filtered_lane_weights(strategy: str, stack: jax.Array, byz_f: int) -> jax.Array:
    """Per-worker drop weight in [0, 1] for one robust combine of ``stack``.

    Exact with respect to what the estimator actually discards:

    * ``ef_norm_filter`` — 1.0 for the ``f`` lanes the (stable-argsort)
      filter dropped, 0.0 for survivors; recomputes the same center/distance/
      order values as :func:`norm_filtered_mean` so XLA CSE shares them.
    * ``ef_trimmed_mean`` — the fraction of this lane's coordinates that fell
      in the trimmed order-statistic ranks (``< f`` or ``>= W - f`` under the
      same stable sort the mean uses).
    * ``ef_coord_median`` (or ``byz_f == 0``) — zeros: the median has no
      discrete drop set to attribute.
    """
    w = stack.shape[0]
    if byz_f == 0 or strategy == "ef_coord_median":
        return jnp.zeros((w,), jnp.float32)
    if strategy == "ef_trimmed_mean":
        ranks = jnp.argsort(jnp.argsort(stack, axis=0), axis=0)
        dropped = (ranks < byz_f) | (ranks >= w - byz_f)
        return jnp.mean(dropped.astype(jnp.float32), axis=tuple(range(1, stack.ndim)))
    if strategy == "ef_norm_filter":
        center = coord_median(stack)
        d2 = jnp.sum((stack - center[None]) ** 2, axis=tuple(range(1, stack.ndim)))
        order = jnp.argsort(d2)
        keep = jnp.zeros((w,), jnp.float32).at[order[: w - byz_f]].set(1.0)
        return 1.0 - keep
    raise ValueError(f"unknown robust strategy {strategy!r}; options: {ROBUST_STRATEGIES}")


def combine_view(strategy: str, view, byz_f: int) -> jax.Array:
    """Robustly combine one slot-native exchange into a (nb, bs) fp32 update.

    ``view`` is the :class:`repro.comm.exchange.PayloadStack` a backend's
    ``exchange()`` returned. ``byz_f == 0`` collapses to ``view.mean()`` —
    the backend's fused mean fast path where it has one — so the
    declared-honest trajectory stays bitwise-equal to ``ef_allgather`` /
    ``ef_ring`` on that transport; otherwise the estimator reads the decoded
    slot stack.
    """
    if byz_f == 0:
        return view.mean()
    return combine_stack(strategy, view.decoded(), byz_f)


def robust_combine(
    strategy: str,
    comp: Compressor,
    gathered: compressed.BucketPayload,
    bucket_size: int,
    byz_f: int,
) -> jax.Array:
    """Robustly combine W gathered payloads into one (nb, bs) fp32 update.

    The payload-level variant of :func:`combine_view` for callers that hold a
    raw gathered stack rather than an exchange view (the byz bench's
    meshless convergence harness, property tests). ``gathered`` leaves carry
    a leading (W,) worker axis. ``byz_f == 0`` takes the literal decode-mean
    path so the declared-honest combine stays bitwise-equal to the mean.
    """
    if byz_f == 0:
        return compressed.decode_mean_buckets(comp, gathered, bucket_size)
    stack = compressed.decode_buckets_stack(comp, gathered, bucket_size)
    return combine_stack(strategy, stack, byz_f)
