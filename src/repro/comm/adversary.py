"""Fault injection: corrupt selected EF-worker gradient lanes.

The injector runs inside the train step, immediately after the vmap'd
per-worker grad computation (:mod:`repro.train.steps`): the first
``floor(fraction * W)`` lanes of the leading EF-worker axis are replaced
according to the configured attack, before the local optimizer chain and the
EF compression see them — i.e. the adversary is a *worker submitting bad
gradients*, and its own EF residual / momentum state evolves from the
corrupted stream exactly as a real traitor's would.

Attacks (:class:`repro.configs.base.ByzConfig`):

``sign_flip``
    g -> -g. Norm-preserving (defeats plain-norm filtering) and the paper's
    natural foil for sign compression: the lane votes against every
    coordinate.
``scaled_noise``
    g -> scale * N(0, I), drawn per step / per leaf / per lane.
``zero_out``
    g -> 0 — the silent straggler.
``const_drift``
    g -> scale * 1, identical on every adversarial lane — the colluding
    attack that biases a plain mean by ``n_attackers/W * scale`` per step.

Zero attackers is a python-level no-op (the input pytree is returned
unchanged), so byz-disabled trajectories stay bitwise identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ByzConfig


def n_attackers(fraction: float, world: int) -> int:
    """``floor(fraction * W)`` — how many leading lanes the injector owns."""
    return int(fraction * world)


def corrupt_worker_tree(byz: ByzConfig, tree_w, key, *, world: int):
    """Replace lanes ``[0, n_attackers)`` of every leaf per ``byz.attack``.

    ``tree_w`` leaves carry a leading ``world``-sized worker axis. ``key``
    seeds the scaled_noise draw (unused by the deterministic attacks).
    """
    n = n_attackers(byz.fraction, world)
    if n == 0:
        return tree_w
    leaves, treedef = jax.tree.flatten(tree_w)
    bad = jnp.arange(world) < n
    out = []
    for i, g in enumerate(leaves):
        mask = bad.reshape((world,) + (1,) * (g.ndim - 1))
        if byz.attack == "sign_flip":
            evil = -g
        elif byz.attack == "zero_out":
            evil = jnp.zeros_like(g)
        elif byz.attack == "scaled_noise":
            noise = jax.random.normal(jax.random.fold_in(key, i), g.shape, jnp.float32)
            evil = (byz.scale * noise).astype(g.dtype)
        else:  # const_drift — every adversarial lane submits the same vector
            evil = jnp.full_like(g, byz.scale)
        out.append(jnp.where(mask, evil, g))
    return jax.tree.unflatten(treedef, out)
