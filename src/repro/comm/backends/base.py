"""The collective-backend protocol.

A *backend* is the transport of the payload-mean exchange at the heart of the
EF strategies: given this worker's encoded bucket payload (inside the fully-
manual ``shard_map`` of the bucketed aggregator), return either the decoded
(nb, bs) fp32 mean over all W workers (:meth:`decode_mean` — every backend)
or the raw gathered per-worker stack (:meth:`gather_stack` — only backends
that materialize it; the robust order-statistics strategies need the full
stack, which a ring never holds). Strategy semantics — EF residual updates,
wire accounting, robust combines — stay in :mod:`repro.comm.collective`;
backends only move bytes, which is what makes XLA-collective / ppermute-ring
/ Pallas-remote-DMA interchangeable per mesh.

All three implementations are constructed once at import time and registered
in :mod:`repro.comm.backends` under ``BACKENDS``; selection happens through
``comm.backends.resolve(spec, mesh, ef_axes)``.
"""

from __future__ import annotations

import jax

from repro.comm import compressed
from repro.comm.errors import BackendCapabilityError
from repro.core.compressors import Compressor

AxisNames = tuple[str, ...]

# strategies whose exchange is the payload-mean a backend transports. dense /
# majority_vote / ef_alltoall are psum / all-to-all shapes with no per-payload
# hop structure — they run on the XLA backend only.
MEAN_STRATEGIES = ("ef_allgather", "ef_ring")


class CollectiveBackend:
    """One transport for the bucketed EF exchange. Subclasses are stateless;
    everything dynamic arrives per call."""

    name: str = "?"
    #: whether :meth:`gather_stack` is available (robust strategies need it)
    supports_stack: bool = False

    def available(self) -> bool:
        """Whether this backend can run on the current jax backend at all.
        ``resolve`` substitutes a fallback (with a logged reason) when not."""
        return True

    def check(self, strategy: str, comp: Compressor, ef_axes: AxisNames, mesh) -> None:
        """Raise :class:`BackendCapabilityError` if this backend cannot run
        ``strategy`` with ``comp`` on ``mesh``. Called at build time from
        ``CommSpec.validate`` / ``resolve`` — never inside the traced body."""
        from repro.comm import robust

        if strategy in robust.ROBUST_STRATEGIES and not self.supports_stack:
            raise BackendCapabilityError(
                f"robust strategy {strategy!r} needs the full gathered worker "
                f"stack, which the {self.name!r} backend never materializes "
                "(mean-only); use backend='xla'"
            )

    def decode_mean(
        self,
        comp: Compressor,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> jax.Array:
        """Exchange this worker's payload with all W workers and return the
        decoded (nb, bs) fp32 mean. Must be bitwise-identical across backends
        (the parity tests pin it), so replicated out_specs stay honest."""
        raise NotImplementedError

    def gather_stack(
        self, payload: compressed.BucketPayload, ef_axes: AxisNames
    ) -> compressed.BucketPayload:
        """All-gather the payload with a leading (W,) worker axis per leaf."""
        raise BackendCapabilityError(
            f"backend {self.name!r} cannot materialize the gathered stack"
        )
