"""The collective-backend protocol.

A *backend* is the transport of the bucket-payload exchange at the heart of
the EF strategies: given this worker's encoded bucket payload (inside the
fully-manual ``shard_map`` of the bucketed aggregator), :meth:`exchange` it
with all W workers and return a :class:`~repro.comm.exchange.PayloadStack`
view. The consumer picks the reading — ``.mean()`` for the EF mean
strategies (collapsing to the backend's fused transport+decode kernel where
one exists), ``.slots()`` / ``.decoded()`` for the Byzantine-robust order
statistics, which therefore ride every transport. Strategy semantics — EF
residual updates, wire accounting, robust combines — stay in
:mod:`repro.comm.collective`; backends only move bytes, which is what makes
XLA-collective / ppermute-ring / Pallas-remote-DMA interchangeable per mesh.

All three implementations are constructed once at import time and registered
in :mod:`repro.comm.backends` under ``BACKENDS``; selection happens through
``comm.backends.resolve(spec, mesh, ef_axes)``.

The pre-slot-native two-method surface (``decode_mean`` / ``gather_stack`` /
``supports_stack``) survives as deprecation shims below; the warnings are
tier-1 ERRORS via pyproject ``filterwarnings``.
"""

from __future__ import annotations

import warnings

import jax
from jax import lax

from repro.comm import compressed, exchange, robust
from repro.comm.errors import BackendCapabilityError
from repro.core.compressors import Compressor

AxisNames = tuple[str, ...]

# strategies whose exchange is the fused payload mean (a backend may collapse
# transport + decode into per-hop units for these)
MEAN_STRATEGIES = ("ef_allgather", "ef_ring")

# strategies a backend transports at all: the mean family plus the robust
# decodes riding the same slot exchange. dense / majority_vote / ef_alltoall
# are psum / all-to-all shapes with no per-payload hop structure — they run
# on the XLA backend only.
EXCHANGE_STRATEGIES = MEAN_STRATEGIES + robust.ROBUST_STRATEGIES


class CollectiveBackend:
    """One transport for the bucketed EF exchange. Subclasses are stateless;
    everything dynamic arrives per call."""

    name: str = "?"
    #: whether :meth:`exchange` can materialize the canonical origin-id slot
    #: stack (the robust strategies need it). Every in-tree backend can; the
    #: flag is the capability query a mean-only out-of-tree transport trips.
    supports_slots: bool = True
    #: whether the mean reading is a fused transport+decode unit (ring / DMA
    #: hops) rather than gather-then-decode — the overlap pipeline uses this
    #: to place the exchange in its phase structure.
    fused_mean: bool = False

    def available(self) -> bool:
        """Whether this backend can run on the current jax backend at all.
        ``resolve`` substitutes a fallback (with a logged reason) when not."""
        return True

    def check(self, strategy: str, comp: Compressor, ef_axes: AxisNames, mesh) -> None:
        """Raise :class:`BackendCapabilityError` if this backend cannot run
        ``strategy`` with ``comp`` on ``mesh``. Called at build time from
        ``CommSpec.validate`` / ``resolve`` — never inside the traced body."""
        if strategy in robust.ROBUST_STRATEGIES and not self.supports_slots:
            raise BackendCapabilityError(
                f"robust strategy {strategy!r} needs the canonical origin-id "
                f"payload slot stack and backend {self.name!r} declares "
                "supports_slots=False (mean-only transport)"
            )

    def exchange(
        self,
        comp: Compressor | None,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> exchange.PayloadStack:
        """Exchange this worker's payload with all W workers; return the
        slot-native :class:`~repro.comm.exchange.PayloadStack` view. Both
        readings must be bitwise-identical across backends (the parity tests
        pin it), so replicated out_specs stay honest."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    # deprecated pre-slot-native surface (PR 10 migration shims)
    # ------------------------------------------------------------------

    @property
    def supports_stack(self) -> bool:
        warnings.warn(
            "CollectiveBackend.supports_stack is deprecated; every backend "
            "exchanges the slot stack now — query supports_slots / fused_mean",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.supports_slots

    def decode_mean(
        self,
        comp: Compressor,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> jax.Array:
        warnings.warn(
            "CollectiveBackend.decode_mean() is deprecated; use "
            "exchange(...).mean()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.exchange(comp, payload, bucket_size, ef_axes, world).mean()

    def gather_stack(
        self, payload: compressed.BucketPayload, ef_axes: AxisNames
    ) -> compressed.BucketPayload:
        warnings.warn(
            "CollectiveBackend.gather_stack() is deprecated; use "
            "exchange(...).slots()",
            DeprecationWarning,
            stacklevel=2,
        )
        world = 1
        for a in ef_axes:
            world = world * lax.psum(1, a)  # static on both jax dialects
        return self.exchange(None, payload, 0, ef_axes, world).slots()
