"""Pluggable collective backends for the bucketed EF exchange.

The registry behind :func:`repro.comm.api.make_aggregator`: the same
strategy semantics can ride three transports, selected per mesh —

``xla``         ``lax`` collectives (all-gather). Capability-complete: the
                only backend that materializes the gathered per-worker stack
                the robust strategies need. The default.
``ring``        W−1 double-buffered ``lax.ppermute`` hops (promoted from
                ``overlap/ring.py``). Mean-only, single EF axis.
``pallas_dma``  the remote-DMA ring kernel (:mod:`repro.kernels.dma_ring`):
                hops are ``make_async_remote_copy`` issued in-kernel and the
                decode accumulates straight off the compressed slot words —
                no dense per-worker gradient ever lands in HBM. Needs a real
                TPU ring; :func:`resolve` substitutes ``ring`` elsewhere
                (bitwise-equal result) and logs the reason.

Every backend produces the bitwise-identical (nb, bs) mean (the parity tests
pin it), so swapping transports never perturbs a training trajectory.
``backend="auto"`` resolves deterministically: ``ef_ring`` → ``ring``,
everything else → ``xla``, except on a TPU mesh where the DMA-hop latency
model in :mod:`repro.core.aggregation` acts as the accept/reject oracle for
promoting the mean exchange to ``pallas_dma`` (see :func:`recommend_backend`;
the ``backends`` bench suite gates the model).
"""

from __future__ import annotations

import logging

import jax

from repro.comm.backends.base import MEAN_STRATEGIES, CollectiveBackend
from repro.comm.backends.pallas_dma import PallasDmaBackend
from repro.comm.backends.ring import RingBackend, ring_axis, ring_decode_mean
from repro.comm.backends.xla import XlaBackend, gather_payload
from repro.comm.errors import BackendCapabilityError, UnknownBackendError

logger = logging.getLogger(__name__)

BACKENDS: dict[str, CollectiveBackend] = {
    "xla": XlaBackend(),
    "ring": RingBackend(),
    "pallas_dma": PallasDmaBackend(),
}

#: names accepted by ``CommSpec.backend`` ("auto" defers choice to resolve())
BACKEND_CHOICES = ("auto",) + tuple(BACKENDS)


def lookup(name: str) -> CollectiveBackend:
    """Registry lookup; unknown names fail listing the options."""
    if name not in BACKENDS:
        raise UnknownBackendError(
            f"unknown collective backend {name!r}; options: {tuple(BACKENDS)}"
        )
    return BACKENDS[name]


def recommend_backend(
    n_buckets: int, bucket_size: int, world: int, *, bytes_per_us: float | None = None
) -> str:
    """The accept/reject oracle for promoting the mean exchange to the DMA
    ring: same total bytes either way, so the analytic model compares W−1
    hop launches against one collective launch (see
    :func:`repro.core.aggregation.dma_ring_latency_model`)."""
    from repro.core import aggregation

    if world <= 1:
        return "xla"
    kw = {} if bytes_per_us is None else {"bytes_per_us": bytes_per_us}
    model = aggregation.dma_ring_latency_model(n_buckets, bucket_size, world, **kw)
    return "pallas_dma" if model["accept"] else "xla"


def _auto_backend(spec, mesh, ef_axes, layout) -> str:
    from repro.comm import compressed

    if spec.strategy == "ef_ring":
        return "ring"
    if spec.strategy != "ef_allgather":
        return "xla"  # psum / all-to-all shapes; no payload-mean hop structure
    comp = spec.resolved_compressor
    sign = comp is None or compressed._is_sign(comp)
    if (
        BACKENDS["pallas_dma"].available()
        and layout is not None
        and len(ef_axes) == 1
        and sign
    ):
        return recommend_backend(layout.n_buckets, layout.bucket_size, spec.world_of(mesh, ef_axes))
    return "xla"


def resolve(spec, mesh, ef_axes=(), *, layout=None) -> CollectiveBackend:
    """Pick the backend instance for ``spec`` on ``mesh``.

    ``backend="auto"`` is deterministic per mesh (see module docstring);
    an explicit ``pallas_dma`` off-TPU degrades to ``ring`` with a logged
    reason rather than failing, so one spec serves CI and hardware. The
    returned backend has passed its capability check for this spec.
    """
    name = spec.backend or "auto"
    if name == "auto":
        name = _auto_backend(spec, mesh, ef_axes, layout)
    be = lookup(name)
    if name == "pallas_dma" and not BACKENDS["pallas_dma"].available():
        logger.warning(
            "backend 'pallas_dma' needs the TPU remote-DMA ring (jax backend "
            "is %r here); falling back to the 'ring' backend — same W-1 hop "
            "structure, bitwise-equal result",
            jax.default_backend(),
        )
        be = BACKENDS["ring"]
    if spec.strategy not in MEAN_STRATEGIES and be.name != "xla":
        raise BackendCapabilityError(
            f"strategy {spec.strategy!r} has no payload-mean hop structure to "
            f"re-route (backends apply to {MEAN_STRATEGIES}); it runs on the "
            "'xla' backend only"
        )
    be.check(spec.strategy, spec.resolved_compressor, ef_axes, mesh)
    return be


__all__ = [
    "BACKENDS",
    "BACKEND_CHOICES",
    "CollectiveBackend",
    "MEAN_STRATEGIES",
    "gather_payload",
    "lookup",
    "recommend_backend",
    "resolve",
    "ring_axis",
    "ring_decode_mean",
]
