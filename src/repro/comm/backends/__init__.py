"""Pluggable collective backends for the bucketed EF exchange.

The registry behind :func:`repro.comm.api.make_aggregator`: the same
strategy semantics can ride three transports, selected per mesh —

``xla``         ``lax`` collectives (all-gather). The all-gather *is* the
                slot stack; the mean reading decodes it. The default.
``ring``        W−1 double-buffered ``lax.ppermute`` hops (promoted from
                ``overlap/ring.py``). Fused per-hop mean; origin-id slot
                gather for the robust reading. Single EF axis.
``pallas_dma``  the remote-DMA ring kernel (:mod:`repro.kernels.dma_ring`):
                hops are ``make_async_remote_copy`` issued in-kernel and
                both readings stay in the compressed domain — no dense
                per-worker gradient ever lands in HBM. Needs a real TPU
                ring; :func:`resolve` substitutes ``ring`` elsewhere
                (bitwise-equal result) and logs the reason.

Every backend exchanges payloads into one slot-native
:class:`~repro.comm.exchange.PayloadStack` view, and both readings — the
(nb, bs) mean and the canonical (W, ...) slot stack — are bitwise-identical
across transports (the parity tests pin it), so swapping backends never
perturbs a training trajectory, mean-path or robust.
``backend="auto"`` resolves deterministically: ``ef_ring`` → ``ring``,
everything else → ``xla``, except on a TPU mesh where the DMA-hop latency
model in :mod:`repro.core.aggregation` acts as the accept/reject oracle for
promoting the ``ef_allgather`` mean exchange to ``pallas_dma`` (see
:func:`recommend_backend`; the ``backends`` bench suite gates the model).
The robust strategies stay on ``xla`` under ``auto`` — their decode reads
the full slot stack anyway, so the one-collective gather is the
conservative default — but every backend accepts them explicitly.
"""

from __future__ import annotations

import logging

import jax

from repro.comm.backends.base import (
    EXCHANGE_STRATEGIES,
    MEAN_STRATEGIES,
    CollectiveBackend,
)
from repro.comm.backends.pallas_dma import PallasDmaBackend
from repro.comm.backends.ring import (
    RingBackend,
    ring_axis,
    ring_decode_mean,
    ring_gather_slots,
)
from repro.comm.backends.xla import XlaBackend, gather_payload
from repro.comm.errors import BackendCapabilityError, CommSpecError, UnknownBackendError

logger = logging.getLogger(__name__)

BACKENDS: dict[str, CollectiveBackend] = {
    "xla": XlaBackend(),
    "ring": RingBackend(),
    "pallas_dma": PallasDmaBackend(),
}

#: names accepted by ``CommSpec.backend`` ("auto" defers choice to resolve())
BACKEND_CHOICES = ("auto",) + tuple(BACKENDS)


def lookup(name: str) -> CollectiveBackend:
    """Registry lookup; unknown names fail listing the options."""
    if name not in BACKENDS:
        raise UnknownBackendError(
            f"unknown collective backend {name!r}; options: {tuple(BACKENDS)}"
        )
    return BACKENDS[name]


def recommend_backend(
    n_buckets: int, bucket_size: int, world: int, *, bytes_per_us: float | None = None
) -> str:
    """The accept/reject oracle for promoting the mean exchange to the DMA
    ring: same total bytes either way, so the analytic model compares W−1
    hop launches against one collective launch (see
    :func:`repro.core.aggregation.dma_ring_latency_model`)."""
    from repro.core import aggregation

    if world <= 1:
        return "xla"
    kw = {} if bytes_per_us is None else {"bytes_per_us": bytes_per_us}
    model = aggregation.dma_ring_latency_model(n_buckets, bucket_size, world, **kw)
    return "pallas_dma" if model["accept"] else "xla"


def _auto_backend(spec, mesh, ef_axes, layout) -> str:
    from repro.comm import compressed

    if spec.strategy == "ef_ring":
        return "ring"
    if spec.strategy != "ef_allgather":
        # psum / all-to-all shapes (no payload hop structure) and the robust
        # slot readers: one-collective gather is the conservative default
        return "xla"
    comp = spec.resolved_compressor
    sign = comp is None or compressed.is_sign(comp)
    if (
        BACKENDS["pallas_dma"].available()
        and layout is not None
        and len(ef_axes) == 1
        and sign
    ):
        return recommend_backend(layout.n_buckets, layout.bucket_size, spec.world_of(mesh, ef_axes))
    return "xla"


def resolve(spec, mesh, ef_axes=(), *, layout=None) -> CollectiveBackend:
    """Pick the backend instance for ``spec`` on ``mesh``.

    ``backend="auto"`` is deterministic per mesh (see module docstring);
    an explicit ``pallas_dma`` off-TPU degrades to ``ring`` with a logged
    reason rather than failing, so one spec serves CI and hardware. The
    returned backend has passed its capability check for this spec.
    """
    name = spec.backend or "auto"
    if name == "auto":
        name = _auto_backend(spec, mesh, ef_axes, layout)
    be = lookup(name)
    if name == "pallas_dma" and not BACKENDS["pallas_dma"].available():
        logger.warning(
            "backend 'pallas_dma' needs the TPU remote-DMA ring (jax backend "
            "is %r here); falling back to the 'ring' backend — same W-1 hop "
            "structure, bitwise-equal result",
            jax.default_backend(),
        )
        be = BACKENDS["ring"]
    if spec.strategy not in EXCHANGE_STRATEGIES and be.name != "xla":
        raise BackendCapabilityError(
            f"strategy {spec.strategy!r} has no payload exchange to re-route "
            f"(backends apply to {EXCHANGE_STRATEGIES}); it runs on the "
            "'xla' backend only"
        )
    be.check(spec.strategy, spec.resolved_compressor, ef_axes, mesh)
    return be


def capability_matrix(mesh, ef_axes: tuple[str, ...] = ("data",), comp=None) -> dict:
    """strategy × backend capability table, post-resolution semantics.

    Returns ``{strategy: {backend: cell}}`` where a cell is ``"ok"``,
    ``"ok (degrades to 'ring' here)"`` for an unavailable ``pallas_dma``
    that :func:`resolve` would substitute, or ``"-- <reason>"`` quoting the
    :class:`~repro.comm.errors.CommSpecError` the combination raises.
    ``comp=None`` probes each strategy's default (sign) wire format. Used by
    ``launch/dryrun.py`` to surface misconfigurations before compile.
    """
    from repro.comm import collective

    out: dict[str, dict[str, str]] = {}
    for strategy in collective.STRATEGIES:
        row = {}
        for name, be in BACKENDS.items():
            try:
                if strategy not in EXCHANGE_STRATEGIES and name != "xla":
                    raise BackendCapabilityError("no payload exchange to re-route; xla only")
                be.check(strategy, comp, ef_axes, mesh)
            except CommSpecError as e:
                row[name] = f"-- {e}"
            else:
                if name == "pallas_dma" and not be.available():
                    row[name] = "ok (degrades to 'ring' here)"
                else:
                    row[name] = "ok"
        out[strategy] = row
    return out


__all__ = [
    "BACKENDS",
    "BACKEND_CHOICES",
    "CollectiveBackend",
    "EXCHANGE_STRATEGIES",
    "MEAN_STRATEGIES",
    "capability_matrix",
    "gather_payload",
    "lookup",
    "recommend_backend",
    "resolve",
    "ring_axis",
    "ring_decode_mean",
    "ring_gather_slots",
]
