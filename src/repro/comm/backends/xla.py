"""The XLA-collective backend: one ``lax.all_gather`` moves every payload.

This is the transport ``ef_allgather`` (and the robust strategies riding its
wire) always used — promoted behind the backend seam so the ring and DMA
transports are drop-in replacements. The all-gather *is* the slot stack:
``exchange`` gathers eagerly and returns the materialized
:class:`~repro.comm.exchange.PayloadStack`, whose mean reading is the
canonical ``decode_mean_buckets`` over it — the exact gather-then-decode
program of the pre-slot-native ``decode_mean``.
"""

from __future__ import annotations

import jax
from jax import lax

from repro.comm import compressed, exchange
from repro.comm.backends.base import CollectiveBackend
from repro.core.compressors import Compressor
from repro.obs import trace

AxisNames = tuple[str, ...]


def gather_payload(payload: compressed.BucketPayload, ef_axes: AxisNames):
    """all-gather every payload leaf along a new leading worker axis."""
    return jax.tree.map(lambda x: lax.all_gather(x, ef_axes, tiled=False), payload)


class XlaBackend(CollectiveBackend):
    """``lax`` collectives (all-gather); the default, capability-complete
    transport on every mesh."""

    name = "xla"
    fused_mean = False

    def exchange(
        self,
        comp: Compressor | None,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> exchange.PayloadStack:
        with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
            gathered = gather_payload(payload, ef_axes)
        return exchange.PayloadStack(comp, bucket_size, world, slots=gathered)
