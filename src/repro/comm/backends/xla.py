"""The XLA-collective backend: one ``lax.all_gather`` moves every payload.

This is the transport ``ef_allgather`` (and the robust strategies riding its
wire) always used — promoted behind the backend seam so the ring and DMA
transports are drop-in replacements for the mean path. It is also the only
backend that materializes the gathered per-worker stack, which the robust
order-statistics combiners require.
"""

from __future__ import annotations

import jax
from jax import lax

from repro.comm import compressed
from repro.comm.backends.base import CollectiveBackend
from repro.core.compressors import Compressor
from repro.obs import trace

AxisNames = tuple[str, ...]


def gather_payload(payload: compressed.BucketPayload, ef_axes: AxisNames):
    """all-gather every payload leaf along a new leading worker axis."""
    return jax.tree.map(lambda x: lax.all_gather(x, ef_axes, tiled=False), payload)


class XlaBackend(CollectiveBackend):
    """``lax`` collectives (all-gather); the default, capability-complete
    transport on every mesh."""

    name = "xla"
    supports_stack = True

    def decode_mean(
        self,
        comp: Compressor,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> jax.Array:
        with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
            gathered = gather_payload(payload, ef_axes)
        return compressed.decode_mean_buckets(comp, gathered, bucket_size)

    def gather_stack(
        self, payload: compressed.BucketPayload, ef_axes: AxisNames
    ) -> compressed.BucketPayload:
        with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
            return gather_payload(payload, ef_axes)
