"""Double-buffered ring exchange of compressed bucket payloads.

Promoted from ``repro.overlap.ring`` into the collective-backend registry:
the ring is a *transport*, not an overlap feature — ``ef_allgather`` over the
``ring`` backend and the legacy ``ef_ring`` strategy are the same program.

``ef_allgather`` pays its whole wire cost in ONE collective after the last
bucket is compressed. The ring pays the same total bytes as W−1 *hops* of a
single payload each — per-step bytes × (W−1), see
``repro.core.aggregation.bucketed_sign_ring_wire_bytes`` — which is the shape
the overlap pipeline wants: each hop is a small, independently schedulable
unit that the XLA latency-hiding scheduler (or the ``pallas_dma`` backend's
remote-DMA kernel, :mod:`repro.kernels.dma_ring`) can slide under backward
compute.

Mechanics per hop (``lax.ppermute`` to the next worker on the ring):

    carry = (inflight payload, fp32 accumulator)
    hop t: issue ppermute(inflight)            ── the DMA of hop t
           acc ← fused-accumulate(acc, inflight)  ── overlaps the DMA

The payload stays **sign-compressed on the wire for every hop** — workers
circulate the original payloads rather than partial sums, so nothing is
ever re-compressed and both readings of the exchange are BITWISE equal to
the all-gather path on every worker:

* mean reading, ``W ≤ 2`` — per-hop fused decompress-accumulate (the Pallas
  kernel ``kernels.ops.bucket_sign_accumulate``): with at most one remote
  payload the (own + arrival) sum is commutative, so every worker associates
  identically and the decode cost rides the hop instead of piling up at
  the end.
* mean reading, ``W ≥ 3`` — arrival orders are per-worker *rotations*;
  accumulating in arrival order would leave each worker a differently-
  associated fp32 sum, and params the sharding layer believes are replicated
  (out_specs ``P()``) would silently drift apart over a run. Arrivals are
  therefore stored into canonical origin-id slots (same layout
  ``lax.all_gather`` produces) and decoded by the exact decode-mean the
  all-gather strategy uses — identical association on every worker, while
  the wire still moves as W−1 double-buffered hops the overlap schedule can
  slide under compute.
* slot reading (:func:`ring_gather_slots`) — the same origin-id slot store
  for any W; the robust strategies consume it directly, so they ride the
  ring's hop structure with no extra wire.
"""

from __future__ import annotations

import jax
from jax import lax

from repro.comm import compressed, exchange
from repro.comm.backends.base import CollectiveBackend
from repro.comm.errors import BackendCapabilityError
from repro.core.compressors import Compressor

AxisNames = tuple[str, ...]


def ring_axis(ef_axes: AxisNames) -> str:
    """The single mesh axis the ring runs over (multi-axis EF worlds would
    need a linearized neighbor table — not supported)."""
    if len(ef_axes) != 1:
        raise BackendCapabilityError(
            f"ef_ring needs exactly one EF axis, got {ef_axes!r}"
        )
    return ef_axes[0]


def _accumulate(
    comp: Compressor, acc: jax.Array, payload: compressed.BucketPayload, bucket_size: int
) -> jax.Array:
    if compressed.is_sign(comp):
        from repro.kernels import ops

        return ops.bucket_sign_accumulate(acc, payload.data["words"], payload.data["scale"])
    return acc + compressed.decode_buckets(comp, payload, bucket_size)


def ring_gather_slots(
    payload: compressed.BucketPayload, ef_axes: AxisNames, world: int
) -> compressed.BucketPayload:
    """W−1 double-buffered ppermute hops → canonical origin-id slot stack.

    Every payload leaf gains a leading (W,) axis holding worker *i*'s payload
    at index *i* — the exact layout ``lax.all_gather`` produces, assembled
    from per-hop units instead of one collective. Hop *t*'s arrival
    originated at ``(widx − t − 1) mod W``; storing by origin id is what
    makes the stack worker-invariant (replication-safe downstream decodes).
    """
    axis = ring_axis(ef_axes)
    perm = [(i, (i + 1) % world) for i in range(world)]
    widx = lax.axis_index(axis)
    inflight = payload
    slots = jax.tree.map(lambda x: jax.numpy.zeros((world,) + x.shape, x.dtype), payload.data)

    def store(slots, data, origin):
        return jax.tree.map(
            lambda s, x: lax.dynamic_update_index_in_dim(s, x, origin, 0), slots, data
        )

    slots = store(slots, inflight.data, widx)
    for t in range(world - 1):
        nxt = jax.tree.map(lambda x: lax.ppermute(x, axis, perm), inflight.data)
        # the store overlaps the next hop's DMA just like the fused
        # accumulate of the mean path does
        slots = store(slots, nxt, (widx - t - 1) % world)
        inflight = compressed.BucketPayload(data=nxt)
    return compressed.BucketPayload(data=slots)


def ring_decode_mean(
    comp: Compressor,
    payload: compressed.BucketPayload,
    bucket_size: int,
    ef_axes: AxisNames,
    world: int,
) -> jax.Array:
    """W−1 double-buffered ppermute hops → (nb, bs) mean, bitwise equal to
    the all-gather decode-mean on every worker (see module docstring).

    Runs inside the fully-manual ``shard_map`` of the bucketed aggregator;
    ``payload`` is this worker's own encoded buckets. The hop loop is
    unrolled (W is static and small) so every ppermute and the store /
    accumulate it overlaps are separate XLA ops with no false carry
    dependency.
    """
    axis = ring_axis(ef_axes)
    perm = [(i, (i + 1) % world) for i in range(world)]
    inflight = payload

    if world <= 2:
        # fused per-hop accumulate: (own + one arrival) is commutative, so
        # the association is identical on both workers
        nb = jax.tree.leaves(payload.data)[0].shape[0]
        acc = jax.numpy.zeros((nb, bucket_size), jax.numpy.float32)
        for _ in range(world - 1):
            nxt = jax.tree.map(lambda x: lax.ppermute(x, axis, perm), inflight.data)
            acc = _accumulate(comp, acc, inflight, bucket_size)  # overlaps the hop
            inflight = compressed.BucketPayload(data=nxt)
        acc = _accumulate(comp, acc, inflight, bucket_size)
        return acc / world

    # W ≥ 3: canonical origin-id slots + the all-gather path's own decode,
    # so every worker associates the fp32 sum identically (replication-safe)
    return compressed.decode_mean_buckets(
        comp, ring_gather_slots(payload, ef_axes, world), bucket_size
    )


class RingBackend(CollectiveBackend):
    """``lax.ppermute`` double-buffered ring — W−1 per-hop payload units."""

    name = "ring"
    fused_mean = True

    def check(self, strategy: str, comp: Compressor, ef_axes: AxisNames, mesh) -> None:
        super().check(strategy, comp, ef_axes, mesh)
        ring_axis(ef_axes)  # single-axis EF world required

    def exchange(
        self,
        comp: Compressor | None,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> exchange.PayloadStack:
        from repro.obs import trace

        def mean_fn():
            with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
                return ring_decode_mean(comp, payload, bucket_size, ef_axes, world)

        def slots_fn():
            with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
                return ring_gather_slots(payload, ef_axes, world)

        return exchange.PayloadStack(
            comp, bucket_size, world, slots_fn=slots_fn, mean_fn=mean_fn
        )
