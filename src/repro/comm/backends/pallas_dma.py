"""The Pallas remote-DMA ring backend.

Same W−1 hop structure as the ppermute ring, but the hop is a
``pltpu.make_async_remote_copy`` issued from inside one Pallas kernel
(:mod:`repro.kernels.dma_ring`), and both readings of the exchange stay in
the compressed domain: the mean reading decompress-accumulates straight off
the compressed slot words in VMEM (the wire never materializes a dense
per-worker gradient in HBM), and the slot reading hands the robust
strategies the canonical origin-id slots the kernel already gathers —
``(W, nb, bs/32)`` words + ``(W, nb)`` scales, 32× smaller than a gradient
stack. Capability gates:

* needs a real TPU ring — :func:`resolve <repro.comm.backends.resolve>`
  substitutes the ``ring`` backend off-TPU (same hop structure, same bitwise
  result for both readings) and logs the reason, so ``backend="pallas_dma"``
  specs stay portable to CPU CI;
* sign wire formats only — the kernel decodes ``words``/``scale`` payloads;
* single EF axis, like the ppermute ring.
"""

from __future__ import annotations

from repro.comm import compressed, exchange
from repro.comm.backends import ring as ring_backend
from repro.comm.backends.base import CollectiveBackend
from repro.comm.errors import BackendCapabilityError
from repro.core.compressors import Compressor

AxisNames = tuple[str, ...]


class PallasDmaBackend(CollectiveBackend):
    """Remote-DMA ring: compressed payloads circulate as in-kernel RDMA hops."""

    name = "pallas_dma"
    fused_mean = True

    def available(self) -> bool:
        from repro.kernels import dma_ring

        return dma_ring.supported()

    def check(self, strategy: str, comp: Compressor, ef_axes: AxisNames, mesh) -> None:
        super().check(strategy, comp, ef_axes, mesh)
        ring_backend.ring_axis(ef_axes)  # single-axis EF world required
        if comp is not None and not compressed.is_sign(comp):
            raise BackendCapabilityError(
                "backend 'pallas_dma' decodes the sign wire format "
                f"(words/scale payloads) in-kernel; got compressor {comp!r}"
            )

    def exchange(
        self,
        comp: Compressor | None,
        payload: compressed.BucketPayload,
        bucket_size: int,
        ef_axes: AxisNames,
        world: int,
    ) -> exchange.PayloadStack:
        from repro.kernels import dma_ring
        from repro.obs import trace

        def mean_fn():
            with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
                return dma_ring.dma_ring_decode_mean(
                    payload.data["words"], payload.data["scale"], ef_axes, world
                )

        def slots_fn():
            with trace.span(f"{trace.SPAN_COLLECTIVE}.{self.name}"):
                slot_w, slot_s = dma_ring.dma_ring_slot_stack(
                    payload.data["words"], payload.data["scale"], ef_axes, world
                )
            return compressed.BucketPayload(data={"words": slot_w, "scale": slot_s})

        return exchange.PayloadStack(comp, bucket_size, world, slots_fn=slots_fn, mean_fn=mean_fn)
