"""The slot-native exchange view: one payload exchange, two readings.

:class:`PayloadStack` is what every collective backend returns from its
``exchange()``: a *view* of this worker's payload exchanged with all W
workers, readable either as the canonical origin-id slot stack (leading
``(W,)`` axis per leaf, the layout ``lax.all_gather`` produces — what the
Byzantine-robust order statistics consume) or as the decoded ``(nb, bs)``
fp32 mean (what the EF mean strategies consume).

The view is lazy where the transport allows it: everything here happens
under a jax trace, so a reading that is never taken traces *nothing* — a
mean-only consumer of a ring exchange gets exactly the fused per-hop
accumulate program it always got (the backend supplies it as ``mean_fn``),
and the slot gather is simply absent from the compiled program. That is the
mechanism by which retiring the old ``decode_mean``/``gather_stack`` split
keeps every mean-path program bitwise-unchanged while making the slot stack
available on every transport.

Construction per backend:

* slot transports (``xla``) gather eagerly at exchange time and hand the
  materialized stack in as ``slots``; the mean reading is the canonical
  ``decode_mean_buckets`` over it.
* fused transports (``ring``, ``pallas_dma``) hand in both a ``slots_fn``
  (origin-id slot gather) and a ``mean_fn`` (their fused transport+decode
  kernel); the consumer's first reading decides which one is traced.

Readings are memoized, so telemetry reading ``decoded()`` next to a robust
combine traces the slot gather once and XLA CSE sees one collective.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.comm import compressed
from repro.core.compressors import Compressor


class PayloadStack:
    """View of one exchanged bucket-payload stack (see module docstring).

    ``world`` is the static EF world size W; ``comp``/``bucket_size`` are
    what the decode readings need. Exactly one of ``slots`` (materialized
    :class:`~repro.comm.compressed.BucketPayload` with a leading (W,) axis
    per leaf) or ``slots_fn`` (thunk producing it) must be given; ``mean_fn``
    optionally supplies a fused mean fast path that bypasses the slot stack.
    """

    def __init__(
        self,
        comp: Compressor | None,
        bucket_size: int,
        world: int,
        *,
        slots: compressed.BucketPayload | None = None,
        slots_fn: Callable[[], compressed.BucketPayload] | None = None,
        mean_fn: Callable[[], jax.Array] | None = None,
    ):
        if (slots is None) == (slots_fn is None):
            raise ValueError("PayloadStack needs exactly one of slots= / slots_fn=")
        self.comp = comp
        self.bucket_size = bucket_size
        self.world = world
        self._slots = slots
        self._slots_fn = slots_fn
        self._mean_fn = mean_fn
        self._decoded: jax.Array | None = None
        self._mean: jax.Array | None = None

    @property
    def fused_mean(self) -> bool:
        """Whether the mean reading bypasses the slot stack entirely."""
        return self._mean_fn is not None

    def slots(self) -> compressed.BucketPayload:
        """The canonical origin-id slot stack: a ``BucketPayload`` whose
        leaves carry a leading (W,) worker axis, identical on every worker
        regardless of transport (the parity tests pin it)."""
        if self._slots is None:
            self._slots = self._slots_fn()
        return self._slots

    def decoded(self) -> jax.Array:
        """Per-worker reconstructions: (W, nb, bs) fp32 — the robust
        order-statistics input. Memoized so a combine and the telemetry
        lane weights share one decode."""
        if self._decoded is None:
            self._decoded = compressed.decode_buckets_stack(
                self.comp, self.slots(), self.bucket_size
            )
        return self._decoded

    def mean(self) -> jax.Array:
        """The decoded (nb, bs) fp32 mean over all W workers — collapses to
        the backend's fused kernel when one was supplied, else the canonical
        ``decode_mean_buckets`` over the slot stack. Bitwise-identical across
        backends either way."""
        if self._mean is None:
            if self._mean_fn is not None:
                self._mean = self._mean_fn()
            else:
                self._mean = compressed.decode_mean_buckets(
                    self.comp, self.slots(), self.bucket_size
                )
        return self._mean
