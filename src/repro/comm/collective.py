"""Bucketed gradient collectives under fully-manual ``shard_map``.

Every strategy here runs with **all** mesh axes manual. That is the load-
bearing design decision: jaxlib 0.4.x's SPMD partitioner aborts
(``Check failed: sharding.IsManualSubgroup()``) whenever a collective — or
even a ``lax.scan`` — appears inside a *partial*-manual ``shard_map``, which
is why the per-leaf EF strategies in ``repro.core.aggregation`` were
version-keyed xfails. Buckets are dense per-worker stacks with no intra-leaf
sharding left to preserve, so nothing needs to stay GSPMD-auto: the
aggregator body sees its worker's ``(n_buckets, bucket_size)`` slice, runs
per-bucket compression + EF, and exchanges fixed-size payloads with plain
manual collectives. Devices that share a worker (model-parallel replicas)
run the identical exchange redundantly — payloads are tiny (that is the
point of compression) and the result is replicated where the update needs
to land anyway.

Strategies (mirroring ``repro.core.aggregation``):

``dense``          pmean of raw buckets — wire ≈ 2·4·d bytes (ring model).
``ef_allgather``   compress → all-gather payloads → decode-mean; worker EF.
``ef_ring``        same payloads, exchanged as W−1 double-buffered
                   ``ppermute`` hops with a fused decompress-accumulate per
                   hop (:mod:`repro.comm.backends.ring`) — same total bytes
                   as ef_allgather, but in per-hop units the overlap
                   scheduler can slide under backward compute.
``ef_alltoall``    double compression: workers chunk the bucket stream,
                   all-to-all routes chunk *j* to worker *j* (the "server"
                   for those buckets), which decode-means, re-compresses with
                   a server-side EF residual, and all-gathers the result.
                   Wire ≈ 2·d/8 bytes, W-independent.
``majority_vote``  sign-of-sum-of-signs, no EF (the known-brittle baseline).
``ef_coord_median`` / ``ef_trimmed_mean`` / ``ef_norm_filter``
                   Byzantine-robust variants: identical payloads and wire
                   bill as ef_allgather, but the decode combines the
                   per-worker slot stack with an order-statistics estimator
                   (:mod:`repro.comm.robust`) parameterized by the declared
                   adversary budget ``byz_f``. Rides ANY backend's slot
                   exchange (all-gather, ppermute ring, remote-DMA ring);
                   ``byz_f=0`` is bitwise-equal to ef_allgather.

Wire accounting is exact per bucket: a payload for one bucket costs
``comp.wire_bits(bucket_size)`` bits and every strategy counts how many
bucket payloads each device *receives* per step.

The payload exchange itself (the hop structure of ef_allgather / ef_ring /
the robust strategies) is delegated to a pluggable
:class:`~repro.comm.backends.CollectiveBackend`, which returns one slot-native
:class:`~repro.comm.exchange.PayloadStack` view per dtype group — strategy
semantics (EF residual updates, wire accounting, robust combines) stay here;
backends only move bytes. Construct through
:func:`repro.comm.api.make_aggregator`; the kwarg factory below is a
deprecated shim.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm import bucketize, compressed, robust
from repro.core.aggregation import AggInfo
from repro.core.compressors import Compressor, ScaledSignCompressor
from repro.obs import telemetry as obs_telemetry
from repro.utils import compat

AxisNames = tuple[str, ...]

_EF_STRATEGIES = ("ef_allgather", "ef_ring", "ef_alltoall") + robust.ROBUST_STRATEGIES
STRATEGIES = ("dense",) + _EF_STRATEGIES + ("majority_vote",)


def world_size(mesh, ef_axes: AxisNames) -> int:
    w = 1
    for a in ef_axes:
        w *= mesh.shape[a]
    return w


def _worker_index(ef_axes: AxisNames) -> jax.Array:
    """Linearized index of this device's EF worker (row-major over ef_axes)."""
    idx = jnp.int32(0)
    for a in ef_axes:
        size = lax.psum(1, a)  # static on both jax dialects
        idx = idx * size + lax.axis_index(a)
    return idx


def _gather_payload(payload, ef_axes: AxisNames):
    """all-gather every payload leaf along a new leading worker axis."""
    return jax.tree.map(lambda x: lax.all_gather(x, ef_axes, tiled=False), payload)


def _default_backend(strategy: str):
    """Backend when the caller did not resolve one (internal/legacy entry):
    the transport each strategy historically used."""
    from repro.comm import backends

    return backends.BACKENDS["ring" if strategy == "ef_ring" else "xla"]


def _pad_buckets(x: jax.Array, target: int) -> jax.Array:
    """Zero-pad the bucket axis of (nb, bs) up to ``target`` buckets."""
    return jnp.pad(x, ((0, target - x.shape[0]), (0, 0)))


def make_bucketed_aggregator(
    strategy: str,
    comp: Compressor | None,
    layout: bucketize.BucketLayout,
    mesh,
    ef_axes: AxisNames,
    *,
    byz_f: int = 0,
):
    """Deprecated legacy factory — build a :class:`repro.comm.api.CommSpec`
    and call :func:`repro.comm.api.make_aggregator` instead. This shim maps
    the old kwargs onto a spec (``byz_f`` → ``ByzConfig(f=...)``) and routes
    through the one validated construction path; returned aggregators are
    identical.
    """
    warnings.warn(
        "make_bucketed_aggregator() is deprecated; build a CommSpec and call "
        "repro.comm.make_aggregator(spec, layout, mesh, ef_axes)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.comm import api
    from repro.configs.base import ByzConfig

    # negative budgets predate ByzConfig's own range check — surface the
    # canonical ToleranceError, not the config constructor's
    if byz_f < 0:
        robust.validate_tolerance(strategy, byz_f, world_size(mesh, ef_axes))
    spec = api.CommSpec(
        strategy=strategy,
        compressor=comp,
        bucket_size=layout.bucket_size,
        byz=ByzConfig(f=byz_f) if byz_f else None,
    )
    return api.make_aggregator(spec, layout, mesh, ef_axes)


def build_bucketed_aggregator(
    strategy: str,
    comp: Compressor | None,
    layout: bucketize.BucketLayout,
    mesh,
    ef_axes: AxisNames,
    *,
    byz_f: int = 0,
    backend=None,
    telemetry: bool = False,
):
    """Build ``fn(buckets_w, err_w, srv_w, key) -> (agg, new_err_w, new_srv_w,
    info)`` where the ``_w`` pytrees carry a leading stacked EF-world axis
    sharded over ``ef_axes`` and ``agg`` is the replicated aggregated update,
    one ``(n_buckets, bucket_size)`` fp32 array per dtype group.

    Internal constructor behind :func:`repro.comm.api.make_aggregator` —
    assumes the spec-level validation already ran there. ``backend`` is a
    resolved :class:`repro.comm.backends.CollectiveBackend` carrying the
    payload-mean transport (all-gather / ppermute ring / remote-DMA ring);
    ``None`` picks each strategy's historical default. ``byz_f`` is the
    declared adversary budget handed to the robust strategies. ``telemetry``
    adds a :class:`repro.obs.telemetry.Telemetry` aux output on
    ``info.telemetry`` — pure reads of intermediates the body already
    materializes, so the aggregated update / EF-residual trajectory is
    bitwise-identical either way (pinned by tests/test_obs.py).
    """
    comp = comp or ScaledSignCompressor()
    if backend is None:
        backend = _default_backend(strategy)
    w = world_size(mesh, ef_axes)
    bs = layout.bucket_size
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]
    masks = tuple(bucketize.valid_mask(layout, gi) for gi in range(len(layout.groups)))
    bucket_bits = comp.wire_bits(bs)
    has_err = strategy in _EF_STRATEGIES
    has_srv = strategy == "ef_alltoall"

    def body(buckets, err, srv, key):
        outs, new_errs, new_srvs, dens = [], [], [], []
        wire_bits = 0.0
        # telemetry accumulators — per dtype group bits / residual norms,
        # per-lane robust filter weights. Pure reads; dead code when off.
        grp_bits: list[float] = []
        err_norms: list[jax.Array] = []
        lane_w = jnp.zeros((w,), jnp.float32)
        widx = _worker_index(ef_axes)
        for gi, local in enumerate(zip(buckets, err if has_err else buckets)):
            b = local[0][0]  # (nb, bs) this worker's buckets for group gi
            e = local[1][0] if has_err else None
            nb = b.shape[0]
            gkey = None
            if not comp.deterministic:
                gkey = jax.random.fold_in(jax.random.fold_in(key, widx), gi)

            if strategy == "dense":
                outs.append(lax.pmean(b, ef_axes))
                dens.append(jnp.float32(1.0))
                err_norms.append(jnp.float32(0.0))
                wire_bits += 2 * 32 * nb * bs  # fp32 ring all-reduce model
                grp_bits.append(2 * 32 * nb * bs)

            elif strategy == "majority_vote":
                s = jnp.where(b >= 0, 1.0, -1.0)
                tot = lax.psum(s, ef_axes)
                outs.append(jnp.where(tot >= 0, 1.0, -1.0) * masks[gi])
                dens.append(jnp.float32(1.0))
                err_norms.append(jnp.float32(0.0))
                wire_bits += (w - 1) * nb * bs  # d bits per peer payload
                grp_bits.append((w - 1) * nb * bs)

            elif strategy in ("ef_allgather", "ef_ring") or strategy in robust.ROBUST_STRATEGIES:
                payload, ne, d_b = compressed.ef_encode_buckets(
                    comp, b, e, mask=masks[gi], key=gkey
                )
                # ONE slot-native exchange per transport (all-gather /
                # ppermute / remote DMA); the consumer's reading below decides
                # whether the view traces the fused mean or the slot stack
                view = backend.exchange(comp, payload, bs, ef_axes, w)
                if strategy in robust.ROBUST_STRATEGIES and byz_f and telemetry:
                    # decode the stack once, feed both the combine and the
                    # per-lane filter weights — same ops as combine_view
                    stack = view.decoded()
                    outs.append(robust.combine_stack(strategy, stack, byz_f))
                    lane_w = lane_w + robust.filtered_lane_weights(strategy, stack, byz_f)
                elif strategy in robust.ROBUST_STRATEGIES:
                    # byz_f == 0 collapses to view.mean() — the declared-honest
                    # trajectory stays bitwise-equal to ef_allgather/ef_ring on
                    # every backend
                    outs.append(robust.combine_view(strategy, view, byz_f))
                else:
                    outs.append(view.mean())
                new_errs.append(ne[None])
                dens.append(jnp.mean(d_b))
                err_norms.append(obs_telemetry.residual_l2(ne))
                # every backend moves the same (w−1)·nb payloads per device
                wire_bits += (w - 1) * nb * bucket_bits
                grp_bits.append((w - 1) * nb * bucket_bits)

            else:  # ef_alltoall — double compression over bucket shards
                nbw = compressed.server_shard_buckets(nb, w)
                bp, ep = _pad_buckets(b, w * nbw), _pad_buckets(e, w * nbw)
                mp = _pad_buckets(masks[gi], w * nbw)
                payload, ne, d_b = compressed.ef_encode_buckets(comp, bp, ep, mask=mp)
                new_errs.append(ne[:nb][None])
                dens.append(jnp.mean(d_b[:nb]))
                err_norms.append(obs_telemetry.residual_l2(ne[:nb]))
                # route shard j of every worker's stream to worker j
                shards = jax.tree.map(lambda x: x.reshape(w, nbw, *x.shape[1:]), payload)
                routed = jax.tree.map(
                    lambda x: lax.all_to_all(x, ef_axes, split_axis=0, concat_axis=0, tiled=True),
                    shards,
                )
                s_j = compressed.decode_mean_buckets(comp, routed, bs)  # (nbw, bs)
                # server-side EF re-compression of the mean shard
                srv_mask = lax.dynamic_slice_in_dim(mp, widx * nbw, nbw, axis=0)
                q_payload, new_sv, _ = compressed.ef_encode_buckets(
                    comp, s_j, srv[gi][0], mask=srv_mask
                )
                new_srvs.append(new_sv[None])
                gathered = _gather_payload(q_payload, ef_axes)  # leaves (w, nbw, ...)
                flat = jax.tree.map(lambda x: x.reshape(w * nbw, *x.shape[2:]), gathered)
                full = compressed.decode_buckets(comp, compressed.BucketPayload(data=flat.data), bs)
                outs.append(full[:nb])
                # a2a: recv (w−1) shards of nbw payloads; ag: recv (w−1) more
                wire_bits += 2 * (w - 1) * nbw * bucket_bits
                grp_bits.append(2 * (w - 1) * nbw * bucket_bits)

        tele = None
        if telemetry:
            tele = obs_telemetry.Telemetry(
                err_l2=lax.pmean(jnp.stack(err_norms), ef_axes),
                density=lax.pmean(jnp.stack(dens), ef_axes),
                wire_bytes=jnp.float32(wire_bits / 8.0),
                group_bytes=jnp.asarray(grp_bits, jnp.float32) / 8.0,
                filtered_lanes=lane_w,
            )
        info = AggInfo(
            wire_bytes_per_device=jnp.float32(wire_bits / 8.0),
            mean_density=lax.pmean(jnp.mean(jnp.stack(dens)), ef_axes),
            telemetry=tele,
        )
        return (
            tuple(outs),
            tuple(new_errs) if has_err else (),
            tuple(new_srvs) if has_srv else (),
            info,
        )

    n_groups = len(layout.groups)
    stacked = tuple(P(ef) for _ in range(n_groups))
    in_specs = (
        stacked,
        stacked if has_err else (),
        stacked if has_srv else (),
        P(),
    )
    out_specs = (
        tuple(P() for _ in range(n_groups)),
        stacked if has_err else (),
        stacked if has_srv else (),
        AggInfo(
            wire_bytes_per_device=P(),
            mean_density=P(),
            telemetry=obs_telemetry.replicated_specs() if telemetry else None,
        ),
    )
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, manual_axes=None
    )
