"""Bucketed gradient collectives under fully-manual ``shard_map``.

Every strategy here runs with **all** mesh axes manual. That is the load-
bearing design decision: jaxlib 0.4.x's SPMD partitioner aborts
(``Check failed: sharding.IsManualSubgroup()``) whenever a collective — or
even a ``lax.scan`` — appears inside a *partial*-manual ``shard_map``, which
is why the per-leaf EF strategies in ``repro.core.aggregation`` were
version-keyed xfails. Buckets are dense per-worker stacks with no intra-leaf
sharding left to preserve, so nothing needs to stay GSPMD-auto: the
aggregator body sees its worker's ``(n_buckets, bucket_size)`` slice, runs
per-bucket compression + EF, and exchanges fixed-size payloads with plain
manual collectives. Devices that share a worker (model-parallel replicas)
run the identical exchange redundantly — payloads are tiny (that is the
point of compression) and the result is replicated where the update needs
to land anyway.

Strategies (mirroring ``repro.core.aggregation``):

``dense``          pmean of raw buckets — wire ≈ 2·4·d bytes (ring model).
``ef_allgather``   compress → all-gather payloads → decode-mean; worker EF.
``ef_ring``        same payloads, exchanged as W−1 double-buffered
                   ``ppermute`` hops with a fused decompress-accumulate per
                   hop (:mod:`repro.overlap.ring`) — same total bytes as
                   ef_allgather, but in per-hop units the overlap scheduler
                   can slide under backward compute.
``ef_alltoall``    double compression: workers chunk the bucket stream,
                   all-to-all routes chunk *j* to worker *j* (the "server"
                   for those buckets), which decode-means, re-compresses with
                   a server-side EF residual, and all-gathers the result.
                   Wire ≈ 2·d/8 bytes, W-independent.
``majority_vote``  sign-of-sum-of-signs, no EF (the known-brittle baseline).
``ef_coord_median`` / ``ef_trimmed_mean`` / ``ef_norm_filter``
                   Byzantine-robust variants: identical payloads, all-gather
                   and wire bill as ef_allgather, but the decode combines the
                   per-worker stack with an order-statistics estimator
                   (:mod:`repro.comm.robust`) parameterized by the declared
                   adversary budget ``byz_f``. ``byz_f=0`` is bitwise-equal
                   to ef_allgather.

Wire accounting is exact per bucket: a payload for one bucket costs
``comp.wire_bits(bucket_size)`` bits and every strategy counts how many
bucket payloads each device *receives* per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm import bucketize, compressed, robust
from repro.core.aggregation import AggInfo
from repro.core.compressors import Compressor, ScaledSignCompressor
from repro.utils import compat

AxisNames = tuple[str, ...]

_EF_STRATEGIES = ("ef_allgather", "ef_ring", "ef_alltoall") + robust.ROBUST_STRATEGIES
STRATEGIES = ("dense",) + _EF_STRATEGIES + ("majority_vote",)


def world_size(mesh, ef_axes: AxisNames) -> int:
    w = 1
    for a in ef_axes:
        w *= mesh.shape[a]
    return w


def _worker_index(ef_axes: AxisNames) -> jax.Array:
    """Linearized index of this device's EF worker (row-major over ef_axes)."""
    idx = jnp.int32(0)
    for a in ef_axes:
        size = lax.psum(1, a)  # static on both jax dialects
        idx = idx * size + lax.axis_index(a)
    return idx


def _gather_payload(payload, ef_axes: AxisNames):
    """all-gather every payload leaf along a new leading worker axis."""
    return jax.tree.map(lambda x: lax.all_gather(x, ef_axes, tiled=False), payload)


def _pad_buckets(x: jax.Array, target: int) -> jax.Array:
    """Zero-pad the bucket axis of (nb, bs) up to ``target`` buckets."""
    return jnp.pad(x, ((0, target - x.shape[0]), (0, 0)))


def make_bucketed_aggregator(
    strategy: str,
    comp: Compressor | None,
    layout: bucketize.BucketLayout,
    mesh,
    ef_axes: AxisNames,
    *,
    byz_f: int = 0,
):
    """Build ``fn(buckets_w, err_w, srv_w, key) -> (agg, new_err_w, new_srv_w,
    info)`` where the ``_w`` pytrees carry a leading stacked EF-world axis
    sharded over ``ef_axes`` and ``agg`` is the replicated aggregated update,
    one ``(n_buckets, bucket_size)`` fp32 array per dtype group.

    ``byz_f`` is the declared adversary budget handed to the robust
    strategies; invalid combinations (non-robust strategy with ``byz_f`` set,
    or ``2*byz_f >= W``) raise upfront.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown bucketed strategy {strategy!r}; options: {STRATEGIES}")
    comp = comp or ScaledSignCompressor()
    if strategy == "ef_alltoall" and not compressed._is_sign(comp):
        raise ValueError("ef_alltoall supports sign compressors (wire format)")
    if strategy == "ef_ring":
        from repro.overlap import ring as ring_lib

        ring_lib.ring_axis(ef_axes)  # single-axis EF world required
    w = world_size(mesh, ef_axes)
    robust.validate_tolerance(strategy, byz_f, w)
    bs = layout.bucket_size
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]
    masks = tuple(bucketize.valid_mask(layout, gi) for gi in range(len(layout.groups)))
    bucket_bits = comp.wire_bits(bs)
    has_err = strategy in _EF_STRATEGIES
    has_srv = strategy == "ef_alltoall"

    def body(buckets, err, srv, key):
        outs, new_errs, new_srvs, dens = [], [], [], []
        wire_bits = 0.0
        widx = _worker_index(ef_axes)
        for gi, local in enumerate(zip(buckets, err if has_err else buckets)):
            b = local[0][0]  # (nb, bs) this worker's buckets for group gi
            e = local[1][0] if has_err else None
            nb = b.shape[0]
            gkey = None
            if not comp.deterministic:
                gkey = jax.random.fold_in(jax.random.fold_in(key, widx), gi)

            if strategy == "dense":
                outs.append(lax.pmean(b, ef_axes))
                dens.append(jnp.float32(1.0))
                wire_bits += 2 * 32 * nb * bs  # fp32 ring all-reduce model

            elif strategy == "majority_vote":
                s = jnp.where(b >= 0, 1.0, -1.0)
                tot = lax.psum(s, ef_axes)
                outs.append(jnp.where(tot >= 0, 1.0, -1.0) * masks[gi])
                dens.append(jnp.float32(1.0))
                wire_bits += (w - 1) * nb * bs  # d bits per peer payload

            elif strategy == "ef_allgather" or strategy in robust.ROBUST_STRATEGIES:
                payload, ne, d_b = compressed.ef_encode_buckets(
                    comp, b, e, mask=masks[gi], key=gkey
                )
                gathered = _gather_payload(payload, ef_axes)
                if strategy == "ef_allgather":
                    outs.append(compressed.decode_mean_buckets(comp, gathered, bs))
                else:
                    # same payloads, same wire bill — robustness is decode-side
                    outs.append(robust.robust_combine(strategy, comp, gathered, bs, byz_f))
                new_errs.append(ne[None])
                dens.append(jnp.mean(d_b))
                wire_bits += (w - 1) * nb * bucket_bits

            elif strategy == "ef_ring":
                from repro.overlap import ring as ring_lib

                payload, ne, d_b = compressed.ef_encode_buckets(
                    comp, b, e, mask=masks[gi], key=gkey
                )
                outs.append(ring_lib.ring_decode_mean(comp, payload, bs, ef_axes, w))
                new_errs.append(ne[None])
                dens.append(jnp.mean(d_b))
                # same total as all-gather, paid as (w−1) per-hop payloads
                wire_bits += (w - 1) * nb * bucket_bits

            else:  # ef_alltoall — double compression over bucket shards
                nbw = compressed.server_shard_buckets(nb, w)
                bp, ep = _pad_buckets(b, w * nbw), _pad_buckets(e, w * nbw)
                mp = _pad_buckets(masks[gi], w * nbw)
                payload, ne, d_b = compressed.ef_encode_buckets(comp, bp, ep, mask=mp)
                new_errs.append(ne[:nb][None])
                dens.append(jnp.mean(d_b[:nb]))
                # route shard j of every worker's stream to worker j
                shards = jax.tree.map(lambda x: x.reshape(w, nbw, *x.shape[1:]), payload)
                routed = jax.tree.map(
                    lambda x: lax.all_to_all(x, ef_axes, split_axis=0, concat_axis=0, tiled=True),
                    shards,
                )
                s_j = compressed.decode_mean_buckets(comp, routed, bs)  # (nbw, bs)
                # server-side EF re-compression of the mean shard
                srv_mask = lax.dynamic_slice_in_dim(mp, widx * nbw, nbw, axis=0)
                q_payload, new_sv, _ = compressed.ef_encode_buckets(
                    comp, s_j, srv[gi][0], mask=srv_mask
                )
                new_srvs.append(new_sv[None])
                gathered = _gather_payload(q_payload, ef_axes)  # leaves (w, nbw, ...)
                flat = jax.tree.map(lambda x: x.reshape(w * nbw, *x.shape[2:]), gathered)
                full = compressed.decode_buckets(comp, compressed.BucketPayload(data=flat.data), bs)
                outs.append(full[:nb])
                # a2a: recv (w−1) shards of nbw payloads; ag: recv (w−1) more
                wire_bits += 2 * (w - 1) * nbw * bucket_bits

        info = AggInfo(
            wire_bytes_per_device=jnp.float32(wire_bits / 8.0),
            mean_density=lax.pmean(jnp.mean(jnp.stack(dens)), ef_axes),
        )
        return (
            tuple(outs),
            tuple(new_errs) if has_err else (),
            tuple(new_srvs) if has_srv else (),
            info,
        )

    n_groups = len(layout.groups)
    stacked = tuple(P(ef) for _ in range(n_groups))
    in_specs = (
        stacked,
        stacked if has_err else (),
        stacked if has_srv else (),
        P(),
    )
    out_specs = (
        tuple(P() for _ in range(n_groups)),
        stacked if has_err else (),
        stacked if has_srv else (),
        AggInfo(wire_bytes_per_device=P(), mean_density=P()),
    )
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, manual_axes=None
    )
