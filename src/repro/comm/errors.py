"""One error taxonomy for aggregator construction.

Before :class:`repro.comm.api.CommSpec`, the same misconfiguration could be
rejected from three different modules with three unrelated ``ValueError``\\ s
(strategy/bucket guards in ``train/steps.py``, the strategy check in
``comm/collective.py``, ``validate_tolerance`` ordering in ``comm/robust.py``).
Every construction-time rejection now raises a subclass of
:class:`CommSpecError` — still a ``ValueError``, so existing ``pytest.raises``
call sites and downstream ``except ValueError`` handling keep working, but the
class names make the failure *kind* programmatic:

``UnknownStrategyError``     strategy name not in ``comm.collective.STRATEGIES``
``UnknownBackendError``      backend name not in ``comm.backends.BACKENDS``
``BackendCapabilityError``   backend exists but cannot run this spec (a
                             backend declaring ``supports_slots=False`` asked
                             for a robust strategy, multi-axis EF worlds on a
                             ring, non-sign wire formats on the DMA kernel,
                             a non-exchange strategy re-routed off ``xla``,
                             ...)
``ToleranceError``           declared Byzantine budget out of range (the
                             ``2f >= W`` breakdown, negative ``byz_f``, or a
                             budget on a non-robust strategy)
``WireFormatError``          strategy requires a wire format the compressor
                             does not speak (ef_alltoall's double compression
                             assumes sign payloads)
``PathConfigError``          overlap / byz knobs combined with a gradient path
                             that cannot host them (dense or per-leaf)
``FedConfigError``           federated-tier spec rejected (a cohort that
                             resolves to zero sampled clients, participation
                             out of (0, 1], skew knobs out of range, ...)
"""

from __future__ import annotations


class CommSpecError(ValueError):
    """Base of every aggregator-construction rejection."""


class UnknownStrategyError(CommSpecError):
    pass


class UnknownBackendError(CommSpecError):
    pass


class BackendCapabilityError(CommSpecError):
    pass


class ToleranceError(CommSpecError):
    pass


class WireFormatError(CommSpecError):
    pass


class PathConfigError(CommSpecError):
    pass


class FedConfigError(CommSpecError):
    pass
