"""Bucketed, overlap-ready gradient-communication layer.

Real distributed-training systems do not ship gradients leaf-by-leaf: they
flatten the gradient pytree into fixed-size, dtype-homogeneous *buckets* and
run compression + collectives per bucket (dist-EF-SGD, Zheng et al. '19;
PyTorch DDP's gradient bucketing). This package supplies that wire path for
every :class:`repro.core.compressors.Compressor`:

``api``
    :class:`CommSpec` + :func:`make_aggregator` — THE public entry point: one
    frozen spec describing strategy / compressor / bucket size / collective
    backend / byz / overlap, validated once, dispatched to the right path.
``bucketize``
    :class:`BucketLayout` — a static flatten/unflatten plan computed once per
    parameter spec — plus the flatten/unflatten executors.
``compressed``
    Per-bucket compression with error feedback: encode ``p_b = u_b + e_b``,
    decode-and-average gathered payloads, per-bucket wire/density accounting.
``collective``
    The strategy semantics, run under **fully-manual** ``shard_map`` over
    every mesh axis so jax 0.4.37's partial-manual ``IsManualSubgroup`` abort
    is never reachable (collectives over a manual subgroup while other axes
    stay auto is exactly the broken configuration; see
    tests/test_distributed.py).
``exchange``
    :class:`PayloadStack` — the slot-native view every backend returns from
    its exchange: read ``.mean()`` (fused fast path where the transport has
    one) or ``.slots()``/``.decoded()`` (canonical origin-id worker stack).
``backends``
    Pluggable transports for the slot-native payload exchange — ``xla`` (lax
    collectives), ``ring`` (double-buffered ppermute), ``pallas_dma``
    (in-kernel remote-DMA ring) — selected per mesh via
    ``CommSpec.backend`` / ``backends.resolve``. All three serve both
    readings, so the robust strategies ride every transport.
``errors``
    The one :class:`~repro.comm.errors.CommSpecError` taxonomy every
    construction-time rejection raises from.
``robust``
    Byzantine-robust decode-side combiners (coordinate median, trimmed mean,
    distance-to-median filtering) behind the same aggregator seam — the
    ``ef_coord_median`` / ``ef_trimmed_mean`` / ``ef_norm_filter`` strategies.
``adversary``
    Fault injection for the EF-worker gradient lanes (sign flip, scaled
    noise, zero-out, colluding constant drift) driving the byz bench/tests.

The per-leaf strategies in :mod:`repro.core.aggregation` remain the
``bucket_size=None`` fallback — they preserve leaf shardings (no flatten), at
the cost of per-leaf payloads and the partial-manual collective path.
"""

# import order is cycle-load-bearing: bucketize/compressed/exchange are leaf
# modules, robust sits on compressed, collective on both, backends on
# exchange + collective's helpers, api on everything
from repro.comm.bucketize import (
    DEFAULT_BUCKET_SIZE,
    BucketLayout,
    build_layout,
    flatten_buckets,
    unflatten_buckets,
)
from repro.comm.compressed import (
    BucketPayload,
    decode_buckets_stack,
    decode_mean_buckets,
    ef_encode_buckets,
    init_error_buckets,
    init_server_buckets,
    is_sign,
)
from repro.comm.exchange import PayloadStack
from repro.comm.errors import CommSpecError
from repro.comm.robust import ROBUST_STRATEGIES, robust_combine, validate_tolerance
from repro.comm.collective import STRATEGIES, make_bucketed_aggregator
from repro.comm.backends import BACKENDS, resolve
from repro.comm.api import CommSpec, make_aggregator

__all__ = [
    "BACKENDS",
    "BucketLayout",
    "BucketPayload",
    "CommSpec",
    "CommSpecError",
    "DEFAULT_BUCKET_SIZE",
    "PayloadStack",
    "ROBUST_STRATEGIES",
    "STRATEGIES",
    "build_layout",
    "decode_buckets_stack",
    "decode_mean_buckets",
    "ef_encode_buckets",
    "flatten_buckets",
    "init_error_buckets",
    "init_server_buckets",
    "is_sign",
    "make_aggregator",
    "make_bucketed_aggregator",
    "resolve",
    "robust_combine",
    "unflatten_buckets",
    "validate_tolerance",
]
