"""Bucketed, overlap-ready gradient-communication layer.

Real distributed-training systems do not ship gradients leaf-by-leaf: they
flatten the gradient pytree into fixed-size, dtype-homogeneous *buckets* and
run compression + collectives per bucket (dist-EF-SGD, Zheng et al. '19;
PyTorch DDP's gradient bucketing). This package supplies that wire path for
every :class:`repro.core.compressors.Compressor`:

``bucketize``
    :class:`BucketLayout` — a static flatten/unflatten plan computed once per
    parameter spec — plus the flatten/unflatten executors.
``compressed``
    Per-bucket compression with error feedback: encode ``p_b = u_b + e_b``,
    decode-and-average gathered payloads, per-bucket wire/density accounting.
``collective``
    The mesh collectives, run under **fully-manual** ``shard_map`` over every
    mesh axis so jax 0.4.37's partial-manual ``IsManualSubgroup`` abort is
    never reachable (collectives over a manual subgroup while other axes stay
    auto is exactly the broken configuration; see tests/test_distributed.py).
``robust``
    Byzantine-robust decode-side combiners (coordinate median, trimmed mean,
    distance-to-median filtering) behind the same aggregator seam — the
    ``ef_coord_median`` / ``ef_trimmed_mean`` / ``ef_norm_filter`` strategies.
``adversary``
    Fault injection for the EF-worker gradient lanes (sign flip, scaled
    noise, zero-out, colluding constant drift) driving the byz bench/tests.

The per-leaf strategies in :mod:`repro.core.aggregation` remain the
``bucket_size=None`` fallback — they preserve leaf shardings (no flatten), at
the cost of per-leaf payloads and the partial-manual collective path.
"""

from repro.comm.bucketize import (
    BucketLayout,
    build_layout,
    flatten_buckets,
    unflatten_buckets,
)
from repro.comm.collective import make_bucketed_aggregator
from repro.comm.compressed import (
    BucketPayload,
    decode_buckets_stack,
    decode_mean_buckets,
    ef_encode_buckets,
    init_error_buckets,
    init_server_buckets,
)
from repro.comm.robust import ROBUST_STRATEGIES, robust_combine, validate_tolerance

__all__ = [
    "BucketLayout",
    "BucketPayload",
    "ROBUST_STRATEGIES",
    "build_layout",
    "decode_buckets_stack",
    "decode_mean_buckets",
    "ef_encode_buckets",
    "flatten_buckets",
    "init_error_buckets",
    "init_server_buckets",
    "make_bucketed_aggregator",
    "robust_combine",
    "unflatten_buckets",
    "validate_tolerance",
]
