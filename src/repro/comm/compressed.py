"""Per-bucket compression with error feedback.

Every bucket is a fixed-size fp32 vector, so any
:class:`repro.core.compressors.Compressor` lifts over a ``(n_buckets,
bucket_size)`` stack with a single ``vmap`` — payload shapes are uniform
across buckets, which is exactly what makes the wire format realistic
(fixed-size messages, no per-leaf raggedness).

Sign-family compressors take the fused fast path through
``repro.kernels.ops.ef_sign_bucket_step`` (single HBM pass on TPU, jnp
reference elsewhere); everything else goes through the generic vmap path.
Both produce a :class:`BucketPayload` whose leaves carry a leading
``n_buckets`` axis, ready for ``lax.all_gather`` / ``lax.all_to_all`` over
the bucket stream.

EF bookkeeping (paper Alg. 1, per bucket b):

    p_b   = u_b + e_b
    wire  = C(p_b)                      (the payload that ships)
    e_b'  = (p_b - C⁻¹(wire)) · mask    (mask zeroes the padded tail)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import (
    Compressor,
    ScaledSignCompressor,
    UnscaledSignCompressor,
    density,
)
from repro.kernels import ops
from repro.obs import trace

_SIGN_TYPES = (ScaledSignCompressor, UnscaledSignCompressor)


class BucketPayload(NamedTuple):
    """Uniform wire payload for a stack of buckets.

    ``data`` is the compressor-specific payload pytree with a leading
    ``n_buckets`` axis on every leaf (packed sign words + per-bucket scales
    for the sign family).
    """

    data: Any


def init_error_buckets(layout) -> tuple[jax.Array, ...]:
    """Zero EF residuals, one (n_buckets, bucket_size) array per dtype group."""
    return tuple(jnp.zeros((g.n_buckets, layout.bucket_size), jnp.float32) for g in layout.groups)


def server_shard_buckets(n_buckets: int, world: int) -> int:
    """Buckets per worker in the all-to-all server shard (ceil-divided)."""
    return -(-n_buckets // world)


def init_server_buckets(layout, world: int) -> tuple[jax.Array, ...]:
    """Zero server-side EF residuals for double compression: each worker owns
    a ``ceil(n_buckets / world)``-bucket shard of every group's stream."""
    return tuple(
        jnp.zeros((server_shard_buckets(g.n_buckets, world), layout.bucket_size), jnp.float32)
        for g in layout.groups
    )


def is_sign(comp: Compressor) -> bool:
    """Whether ``comp`` ships the packed sign wire format (``words``/``scale``
    payloads) — the family the fused bucket kernels and the DMA ring decode.
    Public since PR 10; call sites should prefer this over the old private
    ``_is_sign`` name."""
    return isinstance(comp, _SIGN_TYPES)


# legacy private alias (pre-PR 10 call sites)
_is_sign = is_sign


def ef_encode_buckets(
    comp: Compressor,
    buckets: jax.Array,
    err: jax.Array,
    *,
    mask: jax.Array | None = None,
    key: jax.Array | None = None,
    keys: jax.Array | None = None,
) -> tuple[BucketPayload, jax.Array, jax.Array]:
    """Compress ``p = buckets + err`` per bucket.

    Returns ``(payload, new_err, per_bucket_density)``; ``new_err`` is masked
    so padding never accumulates residual. ``buckets``/``err`` are
    (n_buckets, bucket_size) fp32.

    For the sign family everything — packed words, scales, residual AND the
    density metric — comes out of the fused kernel's single stats pass; p is
    never materialized here. ``keys`` (a precomputed (nb, 2) u32 stack of
    per-bucket RNG keys) overrides the internal ``split(key, nb)``: the
    overlap executor passes row subsets of the full split so a group-sliced
    encode draws bit-identical randomness to the one-shot encode.
    """
    nb, bs = buckets.shape
    with trace.span(trace.SPAN_COMPRESS):
        if is_sign(comp):
            fixed = None if isinstance(comp, ScaledSignCompressor) else comp.scale
            words, scales, new_err, dens = ops.ef_sign_bucket_step(buckets, err, fixed_scale=fixed)
            payload = BucketPayload(data={"words": words, "scale": scales})
        else:
            p = buckets + err
            dens = jax.vmap(density)(p)
            if keys is None:
                if key is not None and not comp.deterministic:
                    keys = jax.random.split(key, nb)
                else:
                    keys = jnp.zeros((nb, 2), jnp.uint32)

            def one(pb, kb):
                pay = comp.compress(pb, key=kb if not comp.deterministic else None)
                return pay, comp.decompress(pay, bs)

            payload_data, delta = jax.vmap(one)(p, keys)
            payload = BucketPayload(data=payload_data)
            new_err = p - delta
        if mask is not None:
            new_err = new_err * mask
        return payload, new_err, dens


def decode_buckets(comp: Compressor, payload: BucketPayload, bucket_size: int) -> jax.Array:
    """payload → (n_buckets, bucket_size) fp32 reconstruction."""
    with trace.span(trace.SPAN_DECODE):
        if is_sign(comp):
            return ops.bucket_sign_decode(payload.data["words"], payload.data["scale"], bucket_size)
        return jax.vmap(lambda pay: comp.decompress(pay, bucket_size))(payload.data)


def decode_buckets_stack(comp: Compressor, gathered: BucketPayload, bucket_size: int) -> jax.Array:
    """Per-worker reconstructions of W gathered payloads.

    ``gathered`` leaves carry a leading (W,) axis; returns (W, n_buckets,
    bucket_size) fp32 — the robust-aggregation decode path
    (:mod:`repro.comm.robust`), which needs every worker's vector
    materialized for order statistics, unlike the two-buffer running mean of
    :func:`decode_mean_buckets`.
    """
    return jax.vmap(lambda data: decode_buckets(comp, BucketPayload(data=data), bucket_size))(
        gathered.data
    )


def decode_mean_buckets(comp: Compressor, gathered: BucketPayload, bucket_size: int) -> jax.Array:
    """Mean reconstruction of W gathered payloads.

    ``gathered`` leaves carry a leading (W,) axis; returns (n_buckets,
    bucket_size) fp32 — the all-gather decode hot loop of dist-EF-SGD.
    """
    with trace.span(trace.SPAN_DECODE):
        if is_sign(comp):
            return ops.bucket_decompress_mean(gathered.data["words"], gathered.data["scale"])
        w = jax.tree.leaves(gathered.data)[0].shape[0]

        def body(i, acc):
            pay = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, False), gathered.data
            )
            return acc + decode_buckets(comp, BucketPayload(data=pay), bucket_size)

        nb = jax.tree.leaves(gathered.data)[0].shape[1]
        acc = jax.lax.fori_loop(0, w, body, jnp.zeros((nb, bucket_size), jnp.float32))
        return acc / w
