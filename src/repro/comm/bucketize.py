"""Static bucket layout: flatten a gradient pytree into fixed-size buckets.

A :class:`BucketLayout` is computed ONCE per parameter spec (shapes + dtypes
only — works on ``jax.eval_shape`` results, no device data needed) and then
drives jit-compatible flatten/unflatten executors. Leaves are grouped by
dtype (dtype-homogeneous buckets: a real wire format ships bf16 and fp32
payloads separately), concatenated in tree-flatten order, zero-padded to a
whole number of ``bucket_size``-element buckets, and viewed as
``(n_buckets, bucket_size)``.

Padding rules:
  * ``bucket_size`` must be a multiple of 32 so packed-sign payloads have no
    intra-bucket ragged words;
  * only the LAST bucket of each group carries padding; ``group.valid`` is
    the true element count and :func:`valid_mask` the static mask used to
    keep error-feedback residuals out of the padded tail.

Flattening deliberately trades GSPMD leaf-sharding preservation for a
realistic wire path (fixed-size payloads, one collective per bucket stream) —
the per-leaf strategies in ``repro.core.aggregation`` remain available for
giant fsdp-sharded models via ``bucket_size=None``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_SIZE = 1 << 16  # 65536 elems = 256 KiB fp32 — DDP-scale buckets


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside its dtype group's flat span."""

    group: int  # index into BucketLayout.groups
    offset: int  # element offset into the group's (unpadded) flat span
    size: int
    shape: tuple[int, ...]
    dtype: Any


@dataclasses.dataclass(frozen=True)
class BucketGroup:
    """One dtype-homogeneous run of buckets."""

    dtype: Any
    valid: int  # true element count (before padding)
    n_buckets: int


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static flatten/unflatten plan for one pytree structure."""

    bucket_size: int
    treedef: Any
    slots: tuple[LeafSlot, ...]  # one per leaf, tree-flatten order
    groups: tuple[BucketGroup, ...]

    @property
    def n_buckets(self) -> int:
        return sum(g.n_buckets for g in self.groups)

    @property
    def n_elements(self) -> int:
        return sum(g.valid for g in self.groups)

    @property
    def padded_elements(self) -> int:
        return self.n_buckets * self.bucket_size

    @property
    def padding_overhead(self) -> float:
        """Fraction of transmitted elements that are padding."""
        pad = self.padded_elements - self.n_elements
        return pad / self.padded_elements if self.padded_elements else 0.0

    def wire_bits(self, comp) -> int:
        """Exact per-step bits on the wire: every bucket is one fixed-size
        payload of ``comp.wire_bits(bucket_size)`` bits."""
        return self.n_buckets * comp.wire_bits(self.bucket_size)


def build_layout(tree, bucket_size: int = DEFAULT_BUCKET_SIZE) -> BucketLayout:
    """Compute the static bucket layout of ``tree`` (arrays or ShapeDtypeStructs)."""
    if bucket_size <= 0 or bucket_size % 32 != 0:
        raise ValueError(f"bucket_size must be a positive multiple of 32, got {bucket_size}")
    leaves, treedef = jax.tree.flatten(tree)
    group_order: list[Any] = []  # dtype, in first-appearance order
    group_sizes: dict[Any, int] = {}
    slots = []
    for leaf in leaves:
        dt = jnp.dtype(leaf.dtype)
        if dt not in group_sizes:
            group_order.append(dt)
            group_sizes[dt] = 0
        slots.append(
            LeafSlot(
                group=group_order.index(dt),
                offset=group_sizes[dt],
                size=int(leaf.size),
                shape=tuple(leaf.shape),
                dtype=dt,
            )
        )
        group_sizes[dt] += int(leaf.size)
    groups = tuple(
        BucketGroup(
            dtype=dt,
            valid=group_sizes[dt],
            n_buckets=max(1, -(-group_sizes[dt] // bucket_size)),
        )
        for dt in group_order
    )
    return BucketLayout(
        bucket_size=bucket_size,
        treedef=treedef,
        slots=tuple(slots),
        groups=groups,
    )


def flatten_buckets(layout: BucketLayout, tree) -> tuple[jax.Array, ...]:
    """Pytree → one ``(n_buckets, bucket_size)`` fp32 array per dtype group.

    All compression/EF math runs in fp32 regardless of the group dtype; the
    group dtype drives the cast back in :func:`unflatten_buckets` (and the
    wire-byte model of a mixed-precision transport).
    """
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(layout.slots):
        raise ValueError(f"tree has {len(leaves)} leaves, layout expects {len(layout.slots)}")
    per_group: list[list[jax.Array]] = [[] for _ in layout.groups]
    for slot, leaf in zip(layout.slots, leaves):
        if tuple(leaf.shape) != slot.shape:
            raise ValueError(f"leaf shape {leaf.shape} != layout shape {slot.shape}")
        per_group[slot.group].append(leaf.reshape(-1).astype(jnp.float32))
    out = []
    for group, parts in zip(layout.groups, per_group):
        flat = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)
        pad = group.n_buckets * layout.bucket_size - group.valid
        flat = jnp.pad(flat, (0, pad))
        out.append(flat.reshape(group.n_buckets, layout.bucket_size))
    return tuple(out)


def unflatten_buckets(layout: BucketLayout, buckets: tuple[jax.Array, ...]):
    """Inverse of :func:`flatten_buckets`; leaves are cast back to group dtype."""
    if len(buckets) != len(layout.groups):
        raise ValueError(f"got {len(buckets)} bucket arrays, layout has {len(layout.groups)}")
    flats = []
    for group, b in zip(layout.groups, buckets):
        if b.shape != (group.n_buckets, layout.bucket_size):
            raise ValueError(f"bucket array {b.shape} != ({group.n_buckets}, {layout.bucket_size})")
        flats.append(b.reshape(-1))

    def leaf_view(slot: LeafSlot) -> jax.Array:
        flat = flats[slot.group][slot.offset : slot.offset + slot.size]
        return flat.reshape(slot.shape).astype(slot.dtype)

    return jax.tree.unflatten(layout.treedef, [leaf_view(s) for s in layout.slots])


def valid_mask(layout: BucketLayout, group_index: int) -> jax.Array:
    """(n_buckets, bucket_size) f32 mask: 1 on real elements, 0 on padding.

    Error-feedback residuals are multiplied by this so the padded tail never
    accumulates phantom error (sign-decode emits ±scale even where p == 0).
    """
    group = layout.groups[group_index]
    idx = jnp.arange(group.n_buckets * layout.bucket_size)
    mask = (idx < group.valid).astype(jnp.float32)
    return mask.reshape(group.n_buckets, layout.bucket_size)
