"""`CommSpec` — one declarative description of the communication stack.

Everything the trainer needs to know about gradient exchange lives in one
frozen dataclass: *what* to send (``strategy`` + ``compressor`` +
``bucket_size``), *how* to move it (``backend``, resolved per mesh through
:mod:`repro.comm.backends`), and the two optional riders (``overlap``
pipelining, ``byz`` fault injection / tolerance). :func:`make_aggregator` is
the single construction path — it validates the spec once
(:meth:`CommSpec.validate`, the consolidated error taxonomy of
:mod:`repro.comm.errors`), resolves the backend, and dispatches to the
bucketed / overlapped implementation. The old per-path factories
(``make_bucketed_aggregator`` / ``make_overlapped_aggregator``) remain as
thin deprecated shims over this function.

Validation ordering is part of the contract (tests pin the messages):
structural checks (unknown strategy/backend, compressor wire-format,
overlap/byz path guards) always run; the world-dependent tolerance check
(``2·byz_f < W``) runs only once ``world`` is known — so a spec can be
validated early at config time and again, fully, at build time.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

from repro.comm import bucketize, compressed, robust
from repro.comm.errors import PathConfigError, UnknownStrategyError, WireFormatError
from repro.configs.base import ByzConfig, OverlapConfig
from repro.core.compressors import Compressor, ScaledSignCompressor, get_compressor

if TYPE_CHECKING:  # repro.fed imports comm.errors — keep the runtime edge one-way
    from repro.fed.spec import FedSpec

AxisNames = tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Declarative spec of the gradient-communication stack.

    ``compressor`` accepts a registry name (``"scaled_sign"``), a
    :class:`Compressor` instance, or ``None`` (strategy default: scaled sign
    for the EF strategies). ``backend`` names a transport from
    ``repro.comm.backends.BACKENDS`` or ``"auto"`` (deterministic per mesh:
    ``ef_ring`` → ``ring``; ``ef_allgather`` on a TPU ring consults the
    DMA-hop latency oracle for ``pallas_dma``; everything else — including
    the robust strategies, whose slot-native decode runs on every backend —
    → ``xla``).
    ``bucket_size=None`` selects the per-leaf fallback path in
    ``repro.core.aggregation`` (train-step only; the bucketed aggregator
    itself always has a layout). ``telemetry`` turns on the in-graph
    :class:`repro.obs.telemetry.Telemetry` aux output (``"off"`` | ``"full"``;
    off compiles to the exact pre-telemetry program).
    """

    strategy: str = "dense"
    compressor: Compressor | str | None = None
    bucket_size: int | None = bucketize.DEFAULT_BUCKET_SIZE
    backend: str = "auto"
    byz: ByzConfig | None = None
    overlap: OverlapConfig | None = None
    telemetry: str = "off"
    # federated rider (repro.fed): simulate a client population over the
    # same bucket wire format — per-round cohorts, FedAvg weights, per-client
    # EF residual pools; None = the data-parallel exchange
    fed: "FedSpec | None" = None

    @property
    def resolved_compressor(self) -> Compressor | None:
        """The compressor instance (registry names resolved), or ``None`` to
        let each path apply its strategy default."""
        if isinstance(self.compressor, str):
            return get_compressor(self.compressor)
        return self.compressor

    @property
    def byz_f(self) -> int:
        """Declared adversary tolerance (0 when no byz rider)."""
        return self.byz.f if self.byz is not None else 0

    def world_of(self, mesh, ef_axes: AxisNames) -> int:
        from repro.comm import collective

        return collective.world_size(mesh, ef_axes)

    def validate(self, *, world: int | None = None, ef_axes: AxisNames | None = None) -> "CommSpec":
        """Raise a :class:`repro.comm.errors.CommSpecError` subclass (all
        ``ValueError``) on any invalid combination; return ``self`` otherwise.

        The one validation site for what used to live in three places
        (``train/steps.py`` path guards, ``collective.py`` strategy checks,
        ``robust.validate_tolerance`` call ordering). ``world``/``ef_axes``
        unlock the mesh-dependent checks; without them only structural
        validation runs.
        """
        from repro.comm import backends, collective

        if self.strategy not in collective.STRATEGIES:
            raise UnknownStrategyError(
                f"unknown bucketed strategy {self.strategy!r}; options: {collective.STRATEGIES}"
            )
        if self.backend not in backends.BACKEND_CHOICES:
            backends.lookup(self.backend)  # raises UnknownBackendError w/ options
        comp = self.resolved_compressor or ScaledSignCompressor()
        if self.strategy == "ef_alltoall" and not compressed.is_sign(comp):
            raise WireFormatError("ef_alltoall supports sign compressors (wire format)")
        if self.overlap is not None and (self.strategy == "dense" or self.bucket_size is None):
            raise PathConfigError(
                "overlap_groups needs the bucketed EF path (an EF strategy with "
                f"bucket_size set); got strategy={self.strategy!r}, "
                f"bucket_size={self.bucket_size!r}"
            )
        if self.byz is not None and (self.strategy == "dense" or self.bucket_size is None):
            raise PathConfigError(
                "byz fault injection / tolerance needs the bucketed EF path (the "
                "adversary owns lanes of the vmap'd worker axis); got "
                f"strategy={self.strategy!r}, bucket_size={self.bucket_size!r}"
            )
        from repro.obs.telemetry import TELEMETRY_CHOICES

        if self.telemetry not in TELEMETRY_CHOICES:
            raise PathConfigError(
                f"unknown telemetry level {self.telemetry!r}; options: {TELEMETRY_CHOICES}"
            )
        if self.telemetry != "off" and (self.strategy == "dense" or self.bucket_size is None):
            raise PathConfigError(
                "in-graph telemetry reads the bucketed aggregator's intermediates "
                "(per-group EF residuals / densities); it needs a bucketed strategy "
                f"with bucket_size set, got strategy={self.strategy!r}, "
                f"bucket_size={self.bucket_size!r}"
            )
        if self.fed is not None:
            if self.strategy == "dense" or self.bucket_size is None:
                raise PathConfigError(
                    "the federated tier consumes the bucketed EF wire format (per-"
                    "client residual pools are (n_clients, n_buckets, bucket_size) "
                    "stacks); it needs an EF strategy with bucket_size set, got "
                    f"strategy={self.strategy!r}, bucket_size={self.bucket_size!r}"
                )
            if self.strategy != "ef_allgather":
                raise PathConfigError(
                    "fed server aggregation is the payload-mean family: use "
                    f"strategy='ef_allgather' with a fed rider, got {self.strategy!r} "
                    "(ring/alltoall hop structure and the robust decodes have no "
                    "server-side analogue yet)"
                )
            if self.byz is not None:
                raise PathConfigError(
                    "byz × fed is not supported yet: client sampling turns the "
                    "declared tolerance into a per-round STOCHASTIC attacker count "
                    "(see ROADMAP); drop the byz rider or the fed rider"
                )
            if self.overlap is not None:
                raise PathConfigError(
                    "overlap pipelines the data-parallel collective with backward "
                    "compute; the fed round is a server-side simulation with no "
                    "collective to hide — drop the overlap rider"
                )
        if ef_axes is not None and self.strategy == "ef_ring":
            backends.ring_axis(ef_axes)  # single-axis EF world required
        if world is not None:
            robust.validate_tolerance(self.strategy, self.byz_f, world)
        return self


def make_aggregator(
    spec: CommSpec,
    layout: bucketize.BucketLayout,
    mesh,
    ef_axes: AxisNames,
    *,
    params=None,
):
    """THE construction path for bucketed aggregators.

    Validates ``spec`` against the mesh, resolves the collective backend, and
    dispatches: ``spec.overlap`` set (and W > 1) builds the async-overlap
    pipelined aggregator — which needs the parameter tree (``params``) to
    derive the reverse-AD group schedule — otherwise the one-shot bucketed
    aggregator. Signature of the returned callable matches the legacy
    factories: ``fn(buckets_w, err_w, srv_w, key) -> (agg, new_err_w,
    new_srv_w, info)``.
    """
    from repro.comm import backends, collective

    w = collective.world_size(mesh, ef_axes)
    spec.validate(world=w, ef_axes=ef_axes)
    comp = spec.resolved_compressor
    backend = backends.resolve(spec, mesh, ef_axes, layout=layout)
    if spec.overlap is not None and w > 1:
        from repro.overlap import pipeline
        from repro.overlap import schedule as overlap_schedule

        if params is None:
            raise PathConfigError(
                "spec.overlap needs the parameter tree to derive the reverse-AD "
                "group schedule; pass params= to make_aggregator"
            )
        sched = overlap_schedule.build_schedule(
            layout, params, n_groups=spec.overlap.n_groups, comp=comp
        )
        return pipeline.build_overlapped_aggregator(
            spec.strategy,
            comp,
            layout,
            sched,
            mesh,
            ef_axes,
            backend=backend,
            telemetry=spec.telemetry == "full",
            byz_f=spec.byz_f,
        )
    return collective.build_bucketed_aggregator(
        spec.strategy,
        comp,
        layout,
        mesh,
        ef_axes,
        byz_f=spec.byz_f,
        backend=backend,
        telemetry=spec.telemetry == "full",
    )
