"""Config registry: one module per assigned architecture (+ the paper's own).

Each ``<arch>.py`` exposes ``CONFIG: ModelConfig`` with the exact assigned
hyper-parameters (source cited in ``source``), plus the registry offers
``reduced(cfg)`` — the ≤2-layer, d_model≤512, ≤4-expert smoke variant the
brief requires for CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

# default pipeline depth for --overlap: 4 groups keeps every stage's
# collective ≥ the per-group compress time on the bench model while the
# first group still issues well before the backward scan finishes
DEFAULT_OVERLAP_GROUPS = 4


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """Async-overlap knobs carried from the launcher into the train step.

    ``n_groups`` is the pipeline depth (bucket groups per step); the EF
    residual layout is schedule-independent, so this can change across
    restarts without invalidating checkpoints.
    """

    n_groups: int = DEFAULT_OVERLAP_GROUPS

    def __post_init__(self):
        if self.n_groups < 1:
            raise ValueError(f"overlap n_groups must be >= 1, got {self.n_groups}")

    @staticmethod
    def from_args(overlap: bool, overlap_groups: int | None) -> "OverlapConfig | None":
        """CLI plumbing: ``--overlap`` switches it on, ``--overlap-groups``
        overrides the depth (and implies ``--overlap``)."""
        if not overlap and overlap_groups is None:
            return None
        if overlap_groups is None:
            return OverlapConfig()
        return OverlapConfig(n_groups=overlap_groups)  # 0/negative: __post_init__ rejects


# attacks the fault injector (repro.comm.adversary) can mount on the
# EF-worker-axis gradient lanes
BYZ_ATTACKS = ("sign_flip", "scaled_noise", "zero_out", "const_drift")


@dataclasses.dataclass(frozen=True)
class ByzConfig:
    """Byzantine knobs: the attack the fault injector mounts and the defense
    budget the robust aggregation strategies assume.

    ``fraction`` selects ``floor(fraction * W)`` adversarial lanes on the EF
    worker axis; ``f`` is the DECLARED tolerance handed to the robust
    strategies (order statistics trimmed / workers filtered). They are
    deliberately separate knobs: over- and under-declared budgets are exactly
    what the byz bench suite measures. ``scale`` sets the magnitude of the
    scaled_noise / const_drift attacks.
    """

    attack: str = "sign_flip"
    fraction: float = 0.0
    scale: float = 10.0
    f: int = 0

    def __post_init__(self):
        if self.attack not in BYZ_ATTACKS:
            raise ValueError(f"unknown byz attack {self.attack!r}; options: {BYZ_ATTACKS}")
        if not 0.0 <= self.fraction < 1.0:
            raise ValueError(f"byz fraction must be in [0, 1), got {self.fraction}")
        if self.f < 0:
            raise ValueError(f"byz tolerance f must be >= 0, got {self.f}")

    @staticmethod
    def from_args(attack, fraction, f, scale=None) -> "ByzConfig | None":
        """CLI plumbing: any of ``--byz-attack`` / ``--byz-fraction`` /
        ``--byz-f`` switches the byz path on; unset knobs keep defaults."""
        if attack is None and fraction is None and f is None:
            return None
        kw = {}
        if attack is not None:
            kw["attack"] = attack
        if fraction is not None:
            kw["fraction"] = fraction
        if f is not None:
            kw["f"] = f
        if scale is not None:
            kw["scale"] = scale
        return ByzConfig(**kw)


ARCH_IDS = [
    "granite_moe_1b_a400m",
    "llama3_2_1b",
    "qwen1_5_4b",
    "llava_next_mistral_7b",
    "falcon_mamba_7b",
    "mistral_nemo_12b",
    "deepseek_7b",
    "jamba_1_5_large_398b",
    "phi3_5_moe_42b_a6_6b",
    "whisper_large_v3",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
# the assignment spells ids with dots/dashes; accept those too
_ALIASES.update({
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "deepseek-7b": "deepseek_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "whisper-large-v3": "whisper_large_v3",
})


def get_config(arch: str) -> ModelConfig:
    arch_id = _ALIASES.get(arch, arch)
    if arch_id not in ARCH_IDS and arch_id != "paper_mlp":
        raise ValueError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family, 2 layers (one hybrid period), tiny dims."""
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    num_kv = min(cfg.num_kv_heads, max(1, num_heads // 2)) if cfg.num_heads else 0
    period = cfg.hybrid_period if cfg.arch_type == "hybrid" else 0
    layers = period if period else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=min(cfg.head_dim, 64) if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        num_patch_tokens=min(cfg.num_patch_tokens, 16) if cfg.num_patch_tokens else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk=64,
    )
