"""whisper-large-v3 [audio] — enc-dec transformer backbone.

The mel-spectrogram + conv feature extractor is STUBBED per the brief's
carve-out: ``input_specs`` supplies (B, 1500, d_model) precomputed frame
embeddings consumed by the 32-layer bidirectional encoder; the 32-layer
decoder cross-attends to the encoder memory. Adaptation note (DESIGN.md):
learned absolute positions are replaced by RoPE so the assigned 32k/500k
decode shapes remain lowerable — Whisper's semantic ceiling is 448 decoder
positions; these shapes exercise the backbone, not ASR fidelity.
[arXiv:2212.04356]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    gated_mlp=False,
    norm_type="layer",
    encoder_layers=32,
    encoder_seq=1500,
    cross_attention=True,
    rope_theta=10_000.0,
    param_dtype="float32",
    compute_dtype="bfloat16",
    source="arXiv:2212.04356",
)
