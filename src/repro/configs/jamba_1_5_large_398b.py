"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every 2nd layer; 94B active. No positional encoding (mamba layers provide
order) → rope_theta=0. [arXiv:2403.19887]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    hybrid_period=8,
    hybrid_attn_index=4,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=0.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2403.19887",
)
