"""mistral-nemo-12b [dense] — 128k-context dense GQA (head_dim 128, not d/H).
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
