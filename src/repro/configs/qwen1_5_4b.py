"""qwen1.5-4b [dense] — MHA (kv=20) with QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:Qwen/Qwen1.5-0.5B (family card; assigned 4b dims)",
)
