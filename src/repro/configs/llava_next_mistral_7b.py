"""llava-next-mistral-7b [vlm] — mistral-7b backbone + anyres patch stub.

The ViT/SigLIP vision tower + projector are STUBBED per the brief's carve-out:
``input_specs`` supplies (B, 576, d_model) precomputed patch embeddings that
are prepended to the text sequence (576 = llava-next base-resolution grid).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,  # mistral-7b backbone window
    num_patch_tokens=576,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
