"""falcon-mamba-7b [ssm] — attention-free mamba-1, d_state=16. [arXiv:2410.05355]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    d_ff=0,  # pure mamba blocks, no MLP sublayer
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    source="arXiv:2410.05355",
)
