"""The paper's contribution: compressors, error feedback, EF optimizers,
and distributed compressed-gradient aggregation."""

from repro.core.compressors import (
    Compressor,
    ScaledSignCompressor,
    UnscaledSignCompressor,
    BlockScaledSignCompressor,
    TopKCompressor,
    RandomKCompressor,
    QSGDCompressor,
    LowRankCompressor,
    IdentityCompressor,
    get_compressor,
    density,
    pack_signs,
    unpack_signs,
    compress_tree,
    roundtrip_tree,
    tree_wire_bits,
)
from repro.core.error_feedback import (
    EFState,
    init_ef_state,
    ef_step,
    error_norm_sq,
    lemma3_bound,
    corrected_density,
)
from repro.core.optim import (
    Transform,
    chain,
    sgd,
    signsgd,
    signum,
    adam,
    ef_sgd,
    ef_transform,
    apply_updates,
    get_optimizer,
    constant_schedule,
    step_decay_schedule,
    cosine_schedule,
)
from repro.core.aggregation import (
    AggState,
    AggInfo,
    init_agg_state,
    aggregate,
    dense_mean,
    ef_allgather,
    ef_alltoall,
    majority_vote,
)
