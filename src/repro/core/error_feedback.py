"""Error feedback (the paper's core contribution, Algorithms 1 & 2).

Single-worker EF-SGD (Algorithm 2):

    p_t     = γ g_t + e_t          # error correction
    Δ_t     = C(p_t)               # compression
    x_{t+1} = x_t − Δ_t            # iterate update
    e_{t+1} = p_t − Δ_t            # residual memory

We expose this as a composable *gradient transform* (optax-style) so it chains
with momentum / weight decay / LR schedules, and as raw per-leaf steps used by
the distributed aggregation paths in :mod:`repro.core.aggregation`.

Conventions: the transform consumes *descent updates* ``u_t`` (i.e. already
scaled by −γ, weight decay applied, etc.) and emits the compressed update
``−Δ_t`` with the same sign convention — algebraically identical to the paper
with p_t = −u_t accounting.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor, density


class EFState(NamedTuple):
    error: Any  # pytree matching params: the residual e_t
    key: jax.Array  # PRNG state for randomized compressors
    steps: jax.Array  # int32 counter


def init_ef_state(params, key: jax.Array | None = None, dtype=None) -> EFState:
    err = jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or jnp.result_type(x.dtype, jnp.float32)),
        params,
    )
    return EFState(
        error=err,
        key=key if key is not None else jax.random.PRNGKey(0),
        steps=jnp.int32(0),
    )


def ef_leaf_step(comp: Compressor, p_flat: jax.Array, *, key=None):
    """One EF compression on a flat corrected step p: returns (Δ, e_new, payload)."""
    payload = comp.compress(p_flat, key=key)
    delta = comp.decompress(payload, p_flat.shape[0])
    return delta, p_flat - delta, payload


def ef_step(comp: Compressor, updates, state: EFState):
    """Leaf-wise EF over a pytree of (already −γ-scaled) updates.

    Returns (compressed_updates, new_state). The compression is applied to
    ``p = updates + error`` per leaf via ``comp.apply`` — shape- and
    sharding-preserving (sign compressors are fully elementwise; no reshapes
    of fsdp-sharded leaves).
    """
    key, sub = jax.random.split(state.key)
    leaves, treedef = jax.tree.flatten(updates)
    err_leaves = jax.tree.leaves(state.error)
    keys = list(jax.random.split(sub, len(leaves))) if not comp.deterministic else [None] * len(leaves)

    outs, errs = [], []
    for u, e, k in zip(leaves, err_leaves, keys):
        p = u.astype(e.dtype) + e
        delta = comp.apply(p, key=k).astype(e.dtype)
        outs.append(delta.astype(u.dtype))
        errs.append(p - delta)

    new_state = EFState(
        error=jax.tree.unflatten(treedef, errs),
        key=key,
        steps=state.steps + 1,
    )
    return jax.tree.unflatten(treedef, outs), new_state


def error_norm_sq(state: EFState) -> jax.Array:
    """‖e_t‖²₂ over the whole pytree — the quantity bounded by Lemma 3."""
    sq = jax.tree.map(lambda e: jnp.sum(e.astype(jnp.float32) ** 2), state.error)
    return sum(jax.tree.leaves(sq), start=jnp.float32(0.0))


def lemma3_bound(gamma: float, sigma_sq: float, delta: float) -> float:
    """Paper Lemma 3: E‖e_t‖² ≤ 4(1−δ)γ²σ²/δ²."""
    return 4.0 * (1.0 - delta) * gamma * gamma * sigma_sq / (delta * delta)


def corrected_density(updates, state: EFState):
    """Per-leaf density φ(g_t + e_t) (Fig 2 — what actually governs δ)."""
    return jax.tree.map(
        lambda u, e: density(u.reshape(-1).astype(jnp.float32) + e.reshape(-1)),
        updates,
        state.error,
    )
