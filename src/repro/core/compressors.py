"""Gradient compression operators (paper Assumption A).

A compressor is a map ``C: R^d -> R^d`` that is *δ-approximate over Q*:

    ||C(x) - x||²₂ ≤ (1 - δ) ||x||²₂     ∀ x ∈ Q,  δ ∈ (0, 1].

We additionally expose the *wire format* — the fixed-shape payload that a
worker would actually transmit — because this framework implements the
distributed aggregation path (dense all-reduce vs compressed all-gather vs
all-to-all double compression) explicitly, and the roofline accounting needs
exact on-the-wire byte counts.

Design rules:
  * compressors act on flattened 1-D vectors; `tree_api.py`-style helpers in
    this module lift them leaf-wise over pytrees (the paper's "layer-wise"
    compression, §6.1);
  * compress/decompress are pure, jit-safe, fixed shape (static `n`);
  * each compressor knows its guaranteed δ (or reports the data-dependent
    density φ for the scaled-sign operator, Lemma 8);
  * randomized compressors (random-k, QSGD) take an explicit PRNG key and
    satisfy Assumption A in expectation (allowed by the paper).
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# sign bit packing (wire format shared with the Pallas kernel in repro.kernels)
# ---------------------------------------------------------------------------

PACK_WIDTH = 32


def packed_len(n: int) -> int:
    return (n + PACK_WIDTH - 1) // PACK_WIDTH


def pack_signs(x: Array) -> Array:
    """Pack ``sign(x) ∈ {-1,+1}`` of a 1-D vector into uint32 words.

    Convention: bit = 1 ⟺ x ≥ 0 (the paper's sign operator with sign(0)=+1).
    Padding bits (beyond n) are zero.
    """
    n = x.shape[0]
    m = packed_len(n)
    bits = (x >= 0).astype(jnp.uint32)
    bits = jnp.pad(bits, (0, m * PACK_WIDTH - n))
    bits = bits.reshape(m, PACK_WIDTH)
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    # disjoint bit positions — plain sum assembles the word
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_signs(words: Array, n: int) -> Array:
    """Inverse of :func:`pack_signs`; returns ±1 float32 of length ``n``."""
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(-1)[:n]
    return 2.0 * bits.astype(jnp.float32) - 1.0


def pack_signs_last(x: Array) -> Array:
    """ND bit-packing along the LAST axis only.

    Keeps every leading dim intact so GSPMD shardings on those dims survive —
    flattening a (data×model)-sharded 28.9G-element leaf to 1-D forces XLA to
    replicate it (observed: ~6 TB/device on the 398B config). Last dim is
    padded to a multiple of 32; padding bits are zero.
    """
    last = x.shape[-1]
    m = packed_len(last)
    bits = (x >= 0).astype(jnp.uint32)
    bits = jnp.pad(bits, [(0, 0)] * (x.ndim - 1) + [(0, m * PACK_WIDTH - last)])
    bits = bits.reshape(*x.shape[:-1], m, PACK_WIDTH)
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_signs_last(words: Array, last: int) -> Array:
    """Inverse of :func:`pack_signs_last`: (..., m) u32 → (..., last) ±1 f32."""
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * PACK_WIDTH)
    return 2.0 * bits[..., :last].astype(jnp.float32) - 1.0


def sign_encode(x: Array, scaled: bool = True, fixed_scale: float = 1.0) -> "SignPayload":
    """ND wire encoding of (scaled) sign: last-axis-packed words + fp32 scale."""
    xf = x.astype(jnp.float32)
    if scaled:
        scale = jnp.sum(jnp.abs(xf)) / float(x.size)
    else:
        scale = jnp.float32(fixed_scale)
    return SignPayload(words=pack_signs_last(xf), scale=scale)


def sign_decode(payload: "SignPayload", shape) -> Array:
    return payload.scale * unpack_signs_last(payload.words, shape[-1]).reshape(shape)


def density(v: Array) -> Array:
    """φ(v) = ||v||₁² / (d ||v||₂²) — Lemma 8's compression quality of scaled sign.

    Any rank; NO flatten — ``reshape(-1)`` of a (data×model)-sharded leaf
    forces XLA to replicate it (~3 TiB/device on the 398B multi-pod path),
    and reductions don't need it."""
    vf = v.astype(jnp.float32)
    l1 = jnp.sum(jnp.abs(vf))
    l2sq = jnp.sum(vf * vf)
    return jnp.where(l2sq > 0, l1 * l1 / (float(v.size) * l2sq), jnp.float32(1.0))


# ---------------------------------------------------------------------------
# payloads
# ---------------------------------------------------------------------------


class SignPayload(NamedTuple):
    """Wire format of (scaled) sign compression: d bits + one fp32 scale."""

    words: Array  # uint32 (ceil(n/32),)
    scale: Array  # float32 scalar; ||p||₁/d for scaled sign, γ for unscaled


class BlockSignPayload(NamedTuple):
    words: Array  # uint32 (nblocks, words_per_block)
    scale: Array  # float32 (nblocks,)


class TopKPayload(NamedTuple):
    values: Array  # float32 (k,)
    indices: Array  # int32 (k,)


class QuantPayload(NamedTuple):
    """QSGD-style stochastic quantization: sign·level/s · ||x||₂."""

    levels: Array  # int8 (n,), signed level in [-s, s]
    norm: Array  # float32 scalar


class LowRankPayload(NamedTuple):
    p: Array  # (rows, rank)
    q: Array  # (cols, rank)


class DensePayload(NamedTuple):
    x: Array


# ---------------------------------------------------------------------------
# compressor base
# ---------------------------------------------------------------------------


class Compressor(abc.ABC):
    """δ-approximate compressor over flat vectors with an explicit wire format."""

    name: str = "compressor"

    @abc.abstractmethod
    def compress(self, x: Array, *, key: Array | None = None) -> Any:
        ...

    @abc.abstractmethod
    def decompress(self, payload: Any, n: int) -> Array:
        ...

    def roundtrip(self, x: Array, *, key: Array | None = None) -> Array:
        """Δ = decompress(compress(x)) — what EF subtracts to form the error."""
        return self.decompress(self.compress(x, key=key), x.shape[0])

    def apply(self, x: Array, *, key: Array | None = None) -> Array:
        """Shape/sharding-preserving Δ = C(x) for arbitrary-rank ``x``.

        Used by the single-worker EF optimizer path where no wire payload is
        needed. Default flattens (fine for small leaves / 1-D); sign-type
        compressors override with a fully elementwise version so fsdp-sharded
        leaves are never reshaped.
        """
        flat = x.reshape(-1).astype(jnp.float32)
        return self.roundtrip(flat, key=key).reshape(x.shape).astype(x.dtype)

    @abc.abstractmethod
    def wire_bits(self, n: int) -> int:
        """Bits actually transmitted for an n-element tensor."""

    def delta(self, n: int) -> float | None:
        """Guaranteed δ of Assumption A if known a-priori, else None."""
        return None

    @property
    def deterministic(self) -> bool:
        return True


# ---------------------------------------------------------------------------
# concrete compressors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScaledSignCompressor(Compressor):
    """The paper's EF-SIGNSGD operator: C(v) = (||v||₁/d)·sign(v)  (Lemma 8).

    δ is data-dependent: δ = φ(v) = ||v||₁²/(d ||v||₂²) ∈ [1/d, 1].
    """

    name: str = "scaled_sign"

    def compress(self, x: Array, *, key=None) -> SignPayload:
        x = x.astype(jnp.float32)
        scale = jnp.sum(jnp.abs(x)) / float(x.shape[0])
        return SignPayload(words=pack_signs(x), scale=scale)

    def decompress(self, payload: SignPayload, n: int) -> Array:
        return payload.scale * unpack_signs(payload.words, n)

    def wire_bits(self, n: int) -> int:
        return packed_len(n) * PACK_WIDTH + 32

    def delta(self, n: int) -> float:
        return 1.0 / n  # worst case; realized δ is density(v) (Lemma 8)

    def apply(self, x: Array, *, key=None) -> Array:
        # elementwise, any rank — preserves shardings (no reshape)
        xf = x.astype(jnp.float32)
        scale = jnp.sum(jnp.abs(xf)) / float(x.size)
        return (scale * jnp.where(xf >= 0, 1.0, -1.0)).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class UnscaledSignCompressor(Compressor):
    """Plain sign with a fixed scale — NOT a δ-approximate compressor.

    Included to reproduce the paper's counterexamples (SIGNSGD proper). With
    ``scale=s`` the update is s·sign(v).
    """

    scale: float = 1.0
    name: str = "sign"

    def compress(self, x: Array, *, key=None) -> SignPayload:
        return SignPayload(words=pack_signs(x), scale=jnp.float32(self.scale))

    def decompress(self, payload: SignPayload, n: int) -> Array:
        return payload.scale * unpack_signs(payload.words, n)

    def wire_bits(self, n: int) -> int:
        return packed_len(n) * PACK_WIDTH

    def apply(self, x: Array, *, key=None) -> Array:
        return (self.scale * jnp.where(x >= 0, 1.0, -1.0)).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class BlockScaledSignCompressor(Compressor):
    """Beyond-paper: scaled sign with a per-block L1 scale.

    Per-block scaling raises the effective δ from the *global* density φ(v) to
    the worst per-block density — helpful when leaves mix dense and near-zero
    regions (e.g. sparsely-routed expert gradients). Wire cost: one extra fp32
    per block.
    """

    block: int = 4096
    name: str = "block_scaled_sign"

    def compress(self, x: Array, *, key=None) -> BlockSignPayload:
        x = x.astype(jnp.float32)
        n = x.shape[0]
        nb = (n + self.block - 1) // self.block
        xp = jnp.pad(x, (0, nb * self.block - n)).reshape(nb, self.block)
        # padded tail contributes 0 to the L1 sum; divide by true block sizes
        sizes = jnp.minimum(
            jnp.full((nb,), self.block, jnp.float32),
            n - jnp.arange(nb, dtype=jnp.float32) * self.block,
        )
        scale = jnp.sum(jnp.abs(xp), axis=-1) / sizes
        words = jax.vmap(pack_signs)(xp)
        return BlockSignPayload(words=words, scale=scale)

    def decompress(self, payload: BlockSignPayload, n: int) -> Array:
        nb, wpb = payload.words.shape
        signs = jax.vmap(lambda w: unpack_signs(w, self.block))(payload.words)
        full = (payload.scale[:, None] * signs).reshape(-1)[:n]
        # zero out padding-region signs beyond n is handled by the slice
        return full

    def wire_bits(self, n: int) -> int:
        nb = (n + self.block - 1) // self.block
        return nb * (self.block + 32)

    def delta(self, n: int) -> float:
        return 1.0 / min(n, self.block)


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """top-k magnitude sparsification (Lin et al. '18; Stich et al. '18).

    δ = k/d (Remark 7: top-1 is a 1/d-approximate compressor → EF-SGD becomes
    a convergent greedy coordinate method).
    """

    k: int = 64
    name: str = "top_k"

    def _k(self, n: int) -> int:
        return max(1, min(self.k, n))

    def compress(self, x: Array, *, key=None) -> TopKPayload:
        x = x.astype(jnp.float32)
        k = self._k(x.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        return TopKPayload(values=x[idx], indices=idx.astype(jnp.int32))

    def decompress(self, payload: TopKPayload, n: int) -> Array:
        out = jnp.zeros((n,), jnp.float32)
        return out.at[payload.indices].set(payload.values)

    def wire_bits(self, n: int) -> int:
        return self._k(n) * (32 + 32)

    def delta(self, n: int) -> float:
        return self._k(n) / n


@dataclasses.dataclass(frozen=True)
class RandomKCompressor(Compressor):
    """Uniform random-k sparsification; δ = k/d in expectation."""

    k: int = 64
    rescale: bool = False  # True → unbiased (×d/k), pair with EF per Remark 5
    name: str = "random_k"

    def _k(self, n: int) -> int:
        return max(1, min(self.k, n))

    def compress(self, x: Array, *, key=None) -> TopKPayload:
        assert key is not None, "random_k requires a PRNG key"
        x = x.astype(jnp.float32)
        n = x.shape[0]
        k = self._k(n)
        idx = jax.random.choice(key, n, shape=(k,), replace=False).astype(jnp.int32)
        vals = x[idx]
        if self.rescale:
            vals = vals * (n / k)
        return TopKPayload(values=vals, indices=idx)

    def decompress(self, payload: TopKPayload, n: int) -> Array:
        out = jnp.zeros((n,), jnp.float32)
        return out.at[payload.indices].set(payload.values)

    def wire_bits(self, n: int) -> int:
        return self._k(n) * (32 + 32)

    def delta(self, n: int) -> float | None:
        # expectation-δ = k/n when not rescaled; rescaled (unbiased) variant is
        # used with EF per Remark 5 and has no a-priori Assumption-A δ.
        return None if self.rescale else self._k(n) / n

    @property
    def deterministic(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """QSGD stochastic quantization (Alistarh et al. '17), s uniform levels.

    Unbiased with variance bound E||U(x)||² ≤ k||x||², k = 1 + min(√d/s, d/s²).
    Per Remark 5, we expose ``ef_scaled=True`` which emits U(x)/k so that the
    operator becomes a (1 - 1/k)… i.e. 1/k-approximate compressor suitable for
    error feedback, pushing the k-slowdown into the O(1/T) term.
    """

    s: int = 15  # levels → 4-bit magnitudes + sign (int8 on the wire here)
    ef_scaled: bool = True
    name: str = "qsgd"

    def _k_factor(self, n: int) -> float:
        return 1.0 + min(math.sqrt(n) / self.s, n / (self.s * self.s))

    def compress(self, x: Array, *, key=None) -> QuantPayload:
        assert key is not None, "qsgd requires a PRNG key"
        x = x.astype(jnp.float32)
        norm = jnp.linalg.norm(x)
        safe = jnp.where(norm > 0, norm, 1.0)
        y = jnp.abs(x) / safe * self.s
        low = jnp.floor(y)
        prob = y - low
        u = jax.random.uniform(key, x.shape)
        mag = low + (u < prob)
        levels = (jnp.sign(x) * mag).astype(jnp.int8)
        return QuantPayload(levels=levels, norm=norm)

    def decompress(self, payload: QuantPayload, n: int) -> Array:
        out = payload.norm * payload.levels.astype(jnp.float32) / self.s
        if self.ef_scaled:
            out = out / self._k_factor(n)
        return out

    def wire_bits(self, n: int) -> int:
        bits_per = max(1, math.ceil(math.log2(2 * self.s + 1)))
        return n * bits_per + 32

    def delta(self, n: int) -> float | None:
        if self.ef_scaled:
            return 1.0 / self._k_factor(n)
        return None

    @property
    def deterministic(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class LowRankCompressor(Compressor):
    """Rank-r approximation via subspace (power) iteration — the paper's
    "k-PCA" example (Wang et al. '18 ATOMO / spectral-ATOMO family).

    Operates on a matrix view (rows, cols) of the flat vector: rows is chosen
    as the largest divisor of n that is ≤ √n (cheap static heuristic), so any
    leaf can be compressed. Deterministic given the fixed seed iterate.
    """

    rank: int = 4
    iters: int = 2
    name: str = "low_rank"

    @staticmethod
    def _shape(n: int) -> tuple[int, int]:
        r = int(math.isqrt(n))
        while r > 1 and n % r != 0:
            r -= 1
        return (r, n // r)

    def compress(self, x: Array, *, key=None) -> LowRankPayload:
        x = x.astype(jnp.float32)
        n = x.shape[0]
        rows, cols = self._shape(n)
        m = x.reshape(rows, cols)
        r = max(1, min(self.rank, rows, cols))
        # deterministic start (shared across workers → no key needed)
        q = jnp.linalg.qr(
            jax.random.normal(jax.random.PRNGKey(0), (cols, r), jnp.float32)
        )[0]
        for _ in range(self.iters):
            p = m @ q  # (rows, r)
            p = jnp.linalg.qr(p)[0]
            q = m.T @ p  # (cols, r)
        return LowRankPayload(p=p, q=q)

    def decompress(self, payload: LowRankPayload, n: int) -> Array:
        return (payload.p @ payload.q.T).reshape(-1)[:n]

    def wire_bits(self, n: int) -> int:
        rows, cols = self._shape(n)
        r = max(1, min(self.rank, rows, cols))
        return 32 * r * (rows + cols)


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    """δ = 1 (no compression) — the dense baseline in compressed codepaths."""

    name: str = "identity"

    def compress(self, x: Array, *, key=None) -> DensePayload:
        return DensePayload(x=x.astype(jnp.float32))

    def decompress(self, payload: DensePayload, n: int) -> Array:
        return payload.x

    def wire_bits(self, n: int) -> int:
        return 32 * n

    def delta(self, n: int) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# pytree lifting (the paper's layer-wise compression)
# ---------------------------------------------------------------------------


def _leaf_keys(key: Array | None, tree) -> Any:
    if key is None:
        return jax.tree.map(lambda _: None, tree)
    leaves, treedef = jax.tree.flatten(tree)
    keys = list(jax.random.split(key, len(leaves)))
    return jax.tree.unflatten(treedef, keys)


def compress_tree(comp: Compressor, tree, *, key: Array | None = None):
    """Apply ``comp`` leaf-wise; returns a pytree of payloads."""
    keys = _leaf_keys(key, tree)
    return jax.tree.map(
        lambda x, k: comp.compress(x.reshape(-1), key=k),
        tree,
        keys,
        is_leaf=lambda x: isinstance(x, jax.Array),
    )


def roundtrip_tree(comp: Compressor, tree, *, key: Array | None = None):
    """Δ-tree = decompress(compress(leaf)) for every leaf, reshaped back."""
    keys = _leaf_keys(key, tree)

    def _rt(x, k):
        flat = x.reshape(-1).astype(jnp.float32)
        return comp.roundtrip(flat, key=k).reshape(x.shape).astype(x.dtype)

    return jax.tree.map(_rt, tree, keys, is_leaf=lambda x: isinstance(x, jax.Array))


def tree_wire_bits(comp: Compressor, tree) -> int:
    """Exact per-step transmission cost (paper §6.1's Σᵢ(dᵢ + 32) accounting)."""
    return sum(comp.wire_bits(x.size) for x in jax.tree.leaves(tree))


def get_compressor(name: str, **kw) -> Compressor:
    table = {
        "scaled_sign": ScaledSignCompressor,
        "sign": UnscaledSignCompressor,
        "block_scaled_sign": BlockScaledSignCompressor,
        "top_k": TopKCompressor,
        "random_k": RandomKCompressor,
        "qsgd": QSGDCompressor,
        "low_rank": LowRankCompressor,
        "identity": IdentityCompressor,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; options: {sorted(table)}")
    return table[name](**kw)
