"""Distributed gradient aggregation strategies (per-LEAF granularity).

The paper analyses single-worker EF-SGD and explicitly names the multi-worker
extension as future work (§7). This module supplies that extension — it is the
piece that turns the paper's operator into a *distributed systems* feature.

This is the ``bucket_size=None`` fallback of the gradient-exchange stack: the
default training path runs the same strategies at fixed-size-BUCKET
granularity through :mod:`repro.comm` (realistic wire format, fully-manual
collectives that survive jaxlib 0.4.x). The per-leaf implementations below
remain for the giant-model dry-run because they are *sharding-preserving*.

All functions here run **inside** ``shard_map`` over the data-parallel mesh
axes (``('data',)`` single-pod tp / ``('pod',)`` multi-pod); the remaining
mesh axes stay in GSPMD-auto mode so tensor/expert/fsdp parallelism composes
below us. For that reason every tensor op here is *sharding-preserving*:
sign payloads are bit-packed along each leaf's LAST axis only (never a full
flatten, which would force XLA to replicate fsdp-sharded leaves), and
decompress-accumulate runs as a fori-loop over workers (two live buffers
instead of a (W, leaf) materialization).

Strategies
----------
dense
    ``lax.pmean`` of fp32 gradients — the SGD baseline; ring all-reduce moves
    ≈ 2·4·d bytes per device.

ef_allgather   (paper-faithful multi-worker EF)
    worker i:  p_i = u_i + e_i ;  payload_i = C(p_i) ;  e_i ← p_i − C⁻¹(payload_i)
    exchange:  all-gather payloads; every worker decompresses all W payloads
    and averages. Wire: (W−1)·(d/8 + 4) bytes received per device for sign —
    a 64/W-fold reduction vs dense; exact at small W, fades as W grows.

ef_alltoall    (beyond paper: double compression, à la DoubleSqueeze/1-bit Adam)
    worker i chunks p_i (last axis) into W pieces and sign-compresses each;
    all-to-all routes chunk j of every worker to worker j; worker j
    decompresses + averages its chunk, re-compresses the mean with a second,
    sharded error buffer (server-side EF), and the result is all-gathered.
    Wire ≈ 2·d/8 bytes — W-independent, the full ~32×.

majority_vote  (Bernstein et al. '19 baseline — known non-convergent cases)
    sign of the sum of signs; no error feedback.

Every strategy returns ``(aggregated_update, new_state, info)`` where ``info``
carries the wire-byte count (used by the roofline cross-check) and the density
φ of the corrected steps (Fig 2).
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compressors import (
    Compressor,
    ScaledSignCompressor,
    SignPayload,
    UnscaledSignCompressor,
    density,
    packed_len,
    sign_decode,
    sign_encode,
    unpack_signs_last,
)

AxisNames = tuple[str, ...]

_SIGN_TYPES = (ScaledSignCompressor, UnscaledSignCompressor)


class AggInfo(NamedTuple):
    wire_bytes_per_device: jax.Array  # what this device receives per step
    mean_density: jax.Array  # mean φ(p) over leaves (Lemma 8 quality)
    # repro.obs.telemetry.Telemetry when CommSpec.telemetry="full"; the None
    # default is an EMPTY pytree child, so off-mode AggInfo has the same two
    # leaves (and the same shard_map out_specs) it always had
    telemetry: Any = None


def info_dict(info: AggInfo) -> dict[str, float]:
    """Pull an AggInfo off-device into plain floats.

    The bench subsystem (repro.bench) and the training-loop metric stream both
    consume this — it is the single place the wire-byte accounting crosses
    from traced values to host-side records.
    """
    return {
        "wire_bytes_per_device": float(info.wire_bytes_per_device),
        "mean_density": float(info.mean_density),
    }


def dense_wire_bytes(n_params: int) -> float:
    """Ring all-reduce wire model for fp32: ≈ 2·4·d bytes per device."""
    return 2.0 * 4.0 * n_params


def sign_allgather_wire_bytes(n_params: int, world: int) -> float:
    """§6.1 accounting: (W−1) payloads of (d + 32·#leaves) bits received;
    single-leaf approximation (d/8 + 4 bytes per payload)."""
    return (world - 1) * (n_params / 8.0 + 4.0)


def bucketed_sign_allgather_wire_bytes(n_buckets: int, bucket_size: int, world: int) -> float:
    """Bucketed ef_allgather wire model: (W−1) sign payloads per bucket, each
    bucket_size bits + one fp32 scale (repro.comm exchange granularity)."""
    return (world - 1) * n_buckets * (bucket_size / 8.0 + 4.0)


def bucketed_sign_alltoall_wire_bytes(n_buckets: int, bucket_size: int, world: int) -> float:
    """Bucketed double compression: each device receives (W−1) bucket-shard
    payloads in the all-to-all and (W−1) more in the final all-gather."""
    shard = -(-n_buckets // world)
    return 2.0 * (world - 1) * shard * (bucket_size / 8.0 + 4.0)


def fed_round_wire_bytes(n_buckets: int, bucket_size: int, cohort: int) -> float:
    """Federated-round wire model (sign family): the server receives one sign
    payload per bucket from each SAMPLED client — ``cohort`` payloads of
    bucket_size bits + one fp32 scale. Only sampled clients pay bytes; the
    bill is independent of ``n_clients`` (repro.fed exchange granularity)."""
    return cohort * n_buckets * (bucket_size / 8.0 + 4.0)


def bucketed_sign_ring_per_step_bytes(n_buckets: int, bucket_size: int) -> float:
    """One ring hop: every device receives one full sign payload per bucket
    (bucket_size bits + one fp32 scale) from its neighbor."""
    return n_buckets * (bucket_size / 8.0 + 4.0)


def bucketed_sign_ring_wire_bytes(n_buckets: int, bucket_size: int, world: int) -> float:
    """Ring exchange total: per-step bytes × (W−1) serial hops — the same
    bill as ef_allgather, paid in (W−1) independently schedulable units."""
    return (world - 1) * bucketed_sign_ring_per_step_bytes(n_buckets, bucket_size)


def bucketed_sign_robust_wire_bytes(n_buckets: int, bucket_size: int, world: int) -> float:
    """Robust variants (coord-median / trimmed-mean / norm-filter) ship
    exactly the ef_allgather payloads over the same all-gather — robustness
    is pure decode-side compute, so the wire bill is identical by design."""
    return bucketed_sign_allgather_wire_bytes(n_buckets, bucket_size, world)


def robust_decode_cost_model(
    n_buckets: int, bucket_size: int, world: int, *, byz_f: int = 1, kind: str = "ef_coord_median"
) -> dict:
    """Analytic decode-side cost of a robust combine (repro.comm.robust).

    What the robust strategies pay for the unchanged wire bill:
    ``stack_hbm_bytes`` is the (W, n_buckets, bucket_size) fp32
    materialization the two-buffer running mean of ef_allgather avoids;
    ``sort_flops`` models the per-coordinate worker-axis sort (W log2 W
    compares); ``reduce_flops`` the estimator-specific combine (mid-select,
    kept-order-stat mean, or distance pass + filtered mean). The byz bench
    suite gates these exactly, like the wire models of the other strategies.
    """
    d = float(n_buckets * bucket_size)
    sort = d * world * math.log2(world) if world > 1 else 0.0
    if kind == "ef_coord_median":
        reduce_flops = d
    elif kind == "ef_trimmed_mean":
        reduce_flops = d * (world - 2 * byz_f)
    elif kind == "ef_norm_filter":
        # distance-to-median pass (3 flops/coord/worker) + filtered mean
        reduce_flops = d * (3 * world + (world - byz_f))
    else:
        raise ValueError(f"unknown robust kind {kind!r}")
    return {
        "stack_hbm_bytes": 4.0 * world * d,
        "sort_flops": float(sort),
        "reduce_flops": float(reduce_flops),
        "total_flops": float(sort + reduce_flops),
    }


def ring_latency_model(
    n_buckets: int, bucket_size: int, world: int, *, bytes_per_us: float
) -> dict:
    """Analytic latency of the ring exchange on a ``bytes_per_us`` wire.

    Returns ``{"steps", "per_step_bytes", "per_step_us", "total_us"}`` — the
    per-step term is what the overlap scheduler hides behind backward
    compute; the bench overlap suite gates these against its baseline just
    like the wire-byte models of the existing strategies.
    """
    steps = max(0, world - 1)
    per_step = bucketed_sign_ring_per_step_bytes(n_buckets, bucket_size)
    per_step_us = per_step / bytes_per_us
    return {
        "steps": steps,
        "per_step_bytes": per_step,
        "per_step_us": per_step_us,
        "total_us": steps * per_step_us,
    }


# reference single-wire bandwidth shared by the analytic latency models and
# the bench suites (src/repro/bench/suites/{overlap,backends}.py)
REF_WIRE_BYTES_PER_US = 1250.0

# per-hop issue overhead of an in-kernel remote DMA: semaphore signal/wait +
# descriptor setup, no XLA collective dispatch on the critical path
DMA_HOP_LAUNCH_US = 1.0
# one-shot XLA collective: runtime dispatch + scheduler fence ahead of the wire
COLLECTIVE_LAUNCH_US = 10.0


def dma_ring_latency_model(
    n_buckets: int,
    bucket_size: int,
    world: int,
    *,
    bytes_per_us: float = REF_WIRE_BYTES_PER_US,
    hop_launch_us: float = DMA_HOP_LAUNCH_US,
    collective_launch_us: float = COLLECTIVE_LAUNCH_US,
) -> dict:
    """Analytic latency of the ``pallas_dma`` backend vs the one-shot
    all-gather — the accept/reject oracle behind ``backend="auto"`` promotion
    (``repro.comm.backends.recommend_backend``) and the ``backends`` bench
    suite's gate.

    Both transports move the identical (W−1)·nb sign payloads, so the
    comparison is pure launch structure: the DMA ring pays ``hop_launch_us``
    per hop (in-kernel semaphore + descriptor issue; the fused
    decompress-accumulate rides the DMA wait, adding nothing to the critical
    path), the all-gather pays one ``collective_launch_us`` dispatch up
    front. ``accept`` is True when the ring's total does not exceed the
    all-gather's — with the defaults that holds up to W−1 ≤ 10 hops, past
    which per-hop overhead has eaten the dispatch saving.
    """
    steps = max(0, world - 1)
    per_hop_bytes = bucketed_sign_ring_per_step_bytes(n_buckets, bucket_size)
    per_hop_us = hop_launch_us + per_hop_bytes / bytes_per_us
    dma_total_us = steps * per_hop_us
    allgather_bytes = bucketed_sign_allgather_wire_bytes(n_buckets, bucket_size, world)
    allgather_us = (collective_launch_us if steps else 0.0) + allgather_bytes / bytes_per_us
    return {
        "steps": steps,
        "per_hop_bytes": per_hop_bytes,
        "per_hop_us": per_hop_us,
        "dma_total_us": dma_total_us,
        "allgather_us": allgather_us,
        "accept": bool(dma_total_us <= allgather_us),
    }


class AggState(NamedTuple):
    worker_error: Any  # per-worker EF residual (pytree like params) or ()
    server_error: Any  # sharded server-side residual for double compression or ()
    key: jax.Array
    steps: jax.Array


def _axis_size(axis_names: AxisNames) -> int:
    w = 1
    for a in axis_names:
        if hasattr(lax, "axis_size"):
            w = w * lax.axis_size(a)
        else:  # jax 0.4.x: psum of a Python 1 folds to the static axis size
            w = w * lax.psum(1, a)
    return w


def _chunk_last(n_last: int, w: int) -> int:
    """Per-worker chunk of the last axis, padded so w·chunk ≥ n_last, %32==0."""
    per = (n_last + w - 1) // w
    return ((per + 31) // 32) * 32


def init_agg_state(
    strategy: str,
    params,
    *,
    world: int = 1,
    seed: int = 0,
    error_dtype=jnp.float32,
    bucket_size: int | None = None,
) -> AggState:
    """Build the aggregation state matching ``strategy``.

    ``world`` is the EF world size. With ``bucket_size`` set (the default
    training path, :mod:`repro.comm`) residuals are held per BUCKET — fp32
    ``(n_buckets, bucket_size)`` stacks per dtype group — and the
    double-compression server error is one bucket shard per worker. With
    ``bucket_size=None`` (per-leaf fallback) residuals mirror the param tree
    and the server error is sharded by last-axis chunk.
    """
    if bucket_size is not None:
        # local import: repro.comm depends on this module for AggInfo
        from repro.comm import bucketize, compressed

        from repro.comm.robust import ROBUST_STRATEGIES

        layout = bucketize.build_layout(params, bucket_size)
        worker_error = (
            compressed.init_error_buckets(layout)
            if strategy in ("ef_allgather", "ef_ring", "ef_alltoall") + ROBUST_STRATEGIES
            else ()
        )
        server_error = (
            compressed.init_server_buckets(layout, world)
            if strategy == "ef_alltoall"
            else ()
        )
        return AggState(
            worker_error=worker_error,
            server_error=server_error,
            key=jax.random.PRNGKey(seed),
            steps=jnp.int32(0),
        )

    zeros = lambda x: jnp.zeros(x.shape, error_dtype)
    worker_error: Any = ()
    server_error: Any = ()
    if strategy == "ef_ring":
        raise ValueError(
            "ef_ring is bucketed-only (repro.overlap.ring): the per-leaf "
            "fallback has no ring implementation — set a bucket_size"
        )
    if strategy in ("ef_coord_median", "ef_trimmed_mean", "ef_norm_filter"):
        raise ValueError(
            f"{strategy} is bucketed-only (repro.comm.robust): the per-leaf "
            "fallback has no robust decode path — set a bucket_size"
        )
    if strategy in ("ef_allgather", "ef_alltoall"):
        worker_error = jax.tree.map(zeros, params)
    if strategy == "ef_alltoall":
        def _server_chunk(x):
            c = _chunk_last(x.shape[-1], world)
            return jnp.zeros(x.shape[:-1] + (c,), error_dtype)

        server_error = jax.tree.map(_server_chunk, params)
    return AggState(
        worker_error=worker_error,
        server_error=server_error,
        key=jax.random.PRNGKey(seed),
        steps=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# dense baseline
# ---------------------------------------------------------------------------


def dense_mean(updates, state: AggState, axis_names: AxisNames, comp=None):
    out = jax.tree.map(lambda u: lax.pmean(u, axis_names), updates)
    nbytes = 2 * 4 * sum(x.size for x in jax.tree.leaves(updates))  # ring AR ≈ 2·d·4B
    info = AggInfo(
        wire_bytes_per_device=jnp.float32(nbytes),
        mean_density=jnp.float32(1.0),
    )
    return out, state._replace(steps=state.steps + 1), info


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _decode_mean_fori(gathered: SignPayload, shape, w: int) -> jax.Array:
    """mean_w scale_w·signs_w with two live buffers (no (W, leaf) blowup)."""
    last = shape[-1]

    def body(i, acc):
        words = lax.dynamic_index_in_dim(gathered.words, i, axis=0, keepdims=False)
        scale = lax.dynamic_index_in_dim(gathered.scale, i, axis=0, keepdims=False)
        return acc + scale * unpack_signs_last(words, last).reshape(shape)

    acc = lax.fori_loop(0, w, body, jnp.zeros(shape, jnp.float32))
    return acc / w


def _generic_roundtrip(comp, p, key):
    flat = p.reshape(-1)
    payload = comp.compress(flat, key=key)
    return payload, comp.decompress(payload, flat.shape[0]).reshape(p.shape)


# ---------------------------------------------------------------------------
# paper-faithful multi-worker EF: compress → all-gather → decompress → mean
# ---------------------------------------------------------------------------


def ef_allgather(
    updates,
    state: AggState,
    axis_names: AxisNames,
    comp: Compressor | None = None,
):
    comp = comp or ScaledSignCompressor()
    is_sign = isinstance(comp, _SIGN_TYPES)
    key, sub = jax.random.split(state.key)
    leaves, treedef = jax.tree.flatten(updates)
    errs = jax.tree.leaves(state.worker_error)
    keys = (
        list(jax.random.split(sub, len(leaves)))
        if not comp.deterministic
        else [None] * len(leaves)
    )
    w = _axis_size(axis_names)

    outs, new_errs, dens, bits = [], [], [], 0
    for u, e, k in zip(leaves, errs, keys):
        p = u.astype(e.dtype) + e
        dens.append(density(p))
        if is_sign:
            payload = sign_encode(p, scaled=isinstance(comp, ScaledSignCompressor))
            delta_local = sign_decode(payload, p.shape)
            gathered = lax.all_gather(payload, axis_names, tiled=False)
            mean = _decode_mean_fori(gathered, p.shape, w)
        else:
            payload, delta_local = _generic_roundtrip(comp, p, k)
            gathered = lax.all_gather(payload, axis_names, tiled=False)
            n = u.size
            delta_all = jax.vmap(lambda pl: comp.decompress(pl, n))(gathered)
            mean = jnp.mean(delta_all, axis=0).reshape(p.shape)
        new_errs.append((p - delta_local).astype(e.dtype))
        outs.append(mean.astype(u.dtype))
        bits += comp.wire_bits(u.size)

    info = AggInfo(
        wire_bytes_per_device=jnp.float32((w - 1) * bits / 8.0),
        mean_density=lax.pmean(jnp.mean(jnp.stack(dens)), axis_names),
    )
    new_state = AggState(
        worker_error=jax.tree.unflatten(treedef, new_errs),
        server_error=state.server_error,
        key=key,
        steps=state.steps + 1,
    )
    return jax.tree.unflatten(treedef, outs), new_state, info


# ---------------------------------------------------------------------------
# beyond paper: all-to-all double compression (W-independent 32×)
# ---------------------------------------------------------------------------


def ef_alltoall(
    updates,
    state: AggState,
    axis_names: AxisNames,
    comp: Compressor | None = None,
):
    comp = comp or ScaledSignCompressor()
    if not isinstance(comp, _SIGN_TYPES):
        raise ValueError("ef_alltoall supports sign compressors (wire format)")
    scaled = isinstance(comp, ScaledSignCompressor)
    w = _axis_size(axis_names)
    leaves, treedef = jax.tree.flatten(updates)
    errs = jax.tree.leaves(state.worker_error)
    srv = jax.tree.leaves(state.server_error)

    outs, new_errs, new_srv, dens, bits = [], [], [], [], 0
    for u, e, se in zip(leaves, errs, srv):
        p = u.astype(e.dtype) + e
        dens.append(density(p))
        last = p.shape[-1]
        c = _chunk_last(last, w)  # == se.shape[-1]
        pp = jnp.pad(p, [(0, 0)] * (p.ndim - 1) + [(0, w * c - last)])
        # chunks on a leading axis: (w, ..., c)
        chunks = jnp.moveaxis(pp.reshape(*p.shape[:-1], w, c), -2, 0)

        # 1) per-chunk compression at the worker
        def enc(x):
            return sign_encode(x, scaled=scaled)

        payload = jax.vmap(enc)(chunks)  # words (w, ..., m), scale (w,)
        delta_chunks = jax.vmap(lambda pl: sign_decode(pl, chunks.shape[1:]))(payload)
        delta_local = jnp.moveaxis(delta_chunks, 0, -2).reshape(*p.shape[:-1], w * c)
        delta_local = delta_local[..., :last]
        new_errs.append((p - delta_local).astype(e.dtype))

        # 2) all-to-all: worker j receives chunk j from every worker
        routed = jax.tree.map(
            lambda x: lax.all_to_all(x, axis_names, split_axis=0, concat_axis=0, tiled=True),
            payload,
        )
        s_j = _decode_mean_fori(routed, chunks.shape[1:], w)  # mean over workers

        # 3) server-side EF re-compression of the mean
        q_in = s_j + se
        q_payload = sign_encode(q_in, scaled=scaled)
        q_delta = sign_decode(q_payload, q_in.shape)
        new_srv.append((q_in - q_delta).astype(se.dtype))

        # 4) all-gather the re-compressed chunk payloads; decode locally
        gathered = lax.all_gather(q_payload, axis_names, tiled=False)  # (w, ..., m)

        def body(i, acc):
            words = lax.dynamic_index_in_dim(gathered.words, i, axis=0, keepdims=False)
            scale = lax.dynamic_index_in_dim(gathered.scale, i, axis=0, keepdims=False)
            chunk = scale * unpack_signs_last(words, c).reshape(q_in.shape)
            return lax.dynamic_update_index_in_dim(acc, chunk, i, axis=0)

        full = lax.fori_loop(0, w, body, jnp.zeros((w,) + q_in.shape, jnp.float32))
        out = jnp.moveaxis(full, 0, -2).reshape(*p.shape[:-1], w * c)[..., :last]
        outs.append(out.astype(u.dtype))

        leaf_rows = math.prod(p.shape[:-1]) if p.ndim > 1 else 1
        chunk_bits = leaf_rows * (packed_len(c) * 32) + 32
        # a2a: recv (w−1) chunks; ag: recv (w−1) chunks
        bits += 2 * (w - 1) * chunk_bits

    info = AggInfo(
        wire_bytes_per_device=jnp.float32(bits / 8.0),
        mean_density=lax.pmean(jnp.mean(jnp.stack(dens)), axis_names),
    )
    new_state = AggState(
        worker_error=jax.tree.unflatten(treedef, new_errs),
        server_error=jax.tree.unflatten(treedef, new_srv),
        key=state.key,
        steps=state.steps + 1,
    )
    return jax.tree.unflatten(treedef, outs), new_state, info


# ---------------------------------------------------------------------------
# majority vote (no EF) — the brittle baseline
# ---------------------------------------------------------------------------


def majority_vote(updates, state: AggState, axis_names: AxisNames, comp=None):
    """x ← x − γ·sign(Σᵢ sign(gᵢ)) — signSGD with majority vote."""

    def _vote(u):
        s = jnp.where(u >= 0, 1.0, -1.0).astype(jnp.float32)
        tot = lax.psum(s, axis_names)
        return jnp.where(tot >= 0, 1.0, -1.0).astype(u.dtype)

    out = jax.tree.map(_vote, updates)
    d = sum(x.size for x in jax.tree.leaves(updates))
    w = _axis_size(axis_names)
    # in practice: all-gather of d-bit payloads + local vote
    info = AggInfo(
        wire_bytes_per_device=jnp.float32((w - 1) * d / 8.0),
        mean_density=jnp.float32(1.0),
    )
    return out, state._replace(steps=state.steps + 1), info


STRATEGIES = {
    "dense": dense_mean,
    "ef_allgather": ef_allgather,
    "ef_alltoall": ef_alltoall,
    "majority_vote": majority_vote,
}


def aggregate(strategy: str, updates, state: AggState, axis_names: AxisNames, comp=None):
    fn = STRATEGIES.get(strategy)
    if fn is None:
        raise ValueError(f"unknown aggregation strategy {strategy!r}")
    return fn(updates, state, axis_names, comp)
