"""Optimizer substrate (optax is not available offline — this is our own).

`Transform` mirrors optax's GradientTransformation: ``init(params) -> state``
and ``update(updates, state, params) -> (updates, state)``. Updates flowing
through a chain are *descent directions*; `apply_updates` adds them.

Algorithms (paper §6.1):
  * ``sgd(lr, momentum)``                      — SGDM baseline
  * ``signsgd(lr, scaled=True)``               — (scaled) SIGNSGD
  * ``signum(lr, beta)``                       — SIGNSGDM, m ← g + βm  (paper's def)
  * ``adam(lr, ...)``                          — for the ADAM≈sign connection
  * ``ef_sgd(lr, compressor, momentum=0)``     — EF-SGD / EF-SIGNSGD (Alg. 1/2)

Schedules: constant, paper's step decimation (/10 at 50%/75% of training),
cosine, linear warmup.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor, ScaledSignCompressor
from repro.core.error_feedback import EFState, ef_step, init_ef_state

Schedule = Callable[[jax.Array], jax.Array]


class Transform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (updates, state, params) -> (updates, state)


class EmptyState(NamedTuple):
    pass


def chain(*transforms: Transform) -> Transform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return Transform(init, update)


def identity() -> Transform:
    return Transform(lambda p: EmptyState(), lambda u, s, p=None: (u, s))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.float32(lr)


def step_decay_schedule(lr: float, total_steps: int, decays=(0.5, 0.75), factor=0.1) -> Schedule:
    """The paper's schedule: decimate at 100 and 150 of 200 epochs."""

    boundaries = jnp.asarray([int(d * total_steps) for d in decays])

    def sched(step):
        k = jnp.sum(step >= boundaries)
        return jnp.float32(lr) * jnp.float32(factor) ** k

    return sched


def cosine_schedule(lr: float, total_steps: int, warmup: int = 0, final_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1.0, warmup))
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total_steps - warmup), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * warm * cos

    return sched


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# basic blocks
# ---------------------------------------------------------------------------


class ScaleByLrState(NamedTuple):
    step: jax.Array


def scale_by_neg_lr(lr) -> Transform:
    sched = _as_schedule(lr)

    def init(params):
        return ScaleByLrState(step=jnp.int32(0))

    def update(updates, state, params=None):
        g = sched(state.step)
        return (
            jax.tree.map(lambda u: -g * u, updates),
            ScaleByLrState(step=state.step + 1),
        )

    return Transform(init, update)


def add_weight_decay(wd: float) -> Transform:
    """g ← g + wd·x (the paper leaves wd = 5e-4 for all methods)."""

    def update(updates, state, params=None):
        if wd == 0.0 or params is None:
            return updates, state
        return (
            jax.tree.map(lambda u, x: u + wd * x.astype(u.dtype), updates, params),
            state,
        )

    return Transform(lambda p: EmptyState(), update)


class TraceState(NamedTuple):
    momentum: Any


def trace(beta: float, nesterov: bool = False) -> Transform:
    """Heavy-ball momentum m ← βm + g (pytorch-style, as in the paper's SGDM)."""

    def init(params):
        return TraceState(jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params))

    def update(updates, state, params=None):
        m = jax.tree.map(lambda mm, u: beta * mm + u.astype(jnp.float32), state.momentum, updates)
        out = jax.tree.map(lambda mm, u: (u.astype(jnp.float32) + beta * mm) if nesterov else mm, m, updates)
        out = jax.tree.map(lambda o, u: o.astype(u.dtype), out, updates)
        return out, TraceState(m)

    return Transform(init, update)


def sign_transform(scaled: bool) -> Transform:
    """u ← sign(u), or the scaled variant (‖u‖₁/d)·sign(u), leaf-wise."""

    def _sign(u):
        s = jnp.where(u >= 0, 1.0, -1.0).astype(jnp.float32)
        if scaled:
            s = s * (jnp.sum(jnp.abs(u.astype(jnp.float32))) / float(u.size))
        return s.astype(u.dtype)

    def update(updates, state, params=None):
        return jax.tree.map(_sign, updates), state

    return Transform(lambda p: EmptyState(), update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def scale_by_adam(b1=0.9, b2=0.999, eps=1e-8) -> Transform:
    def init(params):
        z = lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        return AdamState(mu=z(), nu=z(), step=jnp.int32(0))

    def update(updates, state, params=None):
        step = state.step + 1
        mu = jax.tree.map(lambda m, u: b1 * m + (1 - b1) * u.astype(jnp.float32), state.mu, updates)
        nu = jax.tree.map(lambda v, u: b2 * v + (1 - b2) * u.astype(jnp.float32) ** 2, state.nu, updates)
        t = step.astype(jnp.float32)
        mh = jax.tree.map(lambda m: m / (1 - b1**t), mu)
        nh = jax.tree.map(lambda v: v / (1 - b2**t), nu)
        out = jax.tree.map(lambda m, v, u: (m / (jnp.sqrt(v) + eps)).astype(u.dtype), mh, nh, updates)
        return out, AdamState(mu=mu, nu=nu, step=step)

    return Transform(init, update)


class EFTransformState(NamedTuple):
    ef: EFState


def ef_transform(compressor: Compressor, seed: int = 0, error_dtype=jnp.float32) -> Transform:
    """Error-feedback compression of the (already −γ-scaled) update stream.

    This is Algorithm 2 with p_t ≡ (incoming update) + e_t. Placed *after*
    scale_by_neg_lr in a chain, the emitted update is −Δ_t and the residual is
    exactly the paper's e_{t+1}.
    """

    def init(params):
        return EFTransformState(
            ef=init_ef_state(params, key=jax.random.PRNGKey(seed), dtype=error_dtype)
        )

    def update(updates, state, params=None):
        out, ef = ef_step(compressor, updates, state.ef)
        return out, EFTransformState(ef=ef)

    return Transform(init, update)


# ---------------------------------------------------------------------------
# user-facing optimizers
# ---------------------------------------------------------------------------


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0, nesterov: bool = False) -> Transform:
    parts = [add_weight_decay(weight_decay)]
    if momentum:
        parts.append(trace(momentum, nesterov))
    parts.append(scale_by_neg_lr(lr))
    return chain(*parts)


def signsgd(lr, scaled: bool = True, weight_decay: float = 0.0) -> Transform:
    """(scaled) SIGNSGD: x ← x − γ (‖g‖₁/d)·sign(g)  [or plain sign]."""
    return chain(add_weight_decay(weight_decay), sign_transform(scaled), scale_by_neg_lr(lr))


def signum(lr, beta: float = 0.9, weight_decay: float = 0.0) -> Transform:
    """SIGNSGDM (paper eqn): m ← g + βm; x ← x − γ sign(m)."""

    class SignumState(NamedTuple):
        momentum: Any
        step: jax.Array

    sched = _as_schedule(lr)

    def init(params):
        return SignumState(
            momentum=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
            step=jnp.int32(0),
        )

    def update(updates, state, params=None):
        if weight_decay and params is not None:
            updates = jax.tree.map(lambda u, x: u + weight_decay * x.astype(u.dtype), updates, params)
        m = jax.tree.map(lambda mm, u: u.astype(jnp.float32) + beta * mm, state.momentum, updates)
        g = sched(state.step)
        out = jax.tree.map(lambda mm, u: (-g * jnp.where(mm >= 0, 1.0, -1.0)).astype(u.dtype), m, updates)
        return out, SignumState(momentum=m, step=state.step + 1)

    return Transform(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay: float = 0.0) -> Transform:
    return chain(add_weight_decay(weight_decay), scale_by_adam(b1, b2, eps), scale_by_neg_lr(lr))


def ef_sgd(
    lr,
    compressor: Compressor | None = None,
    momentum: float = 0.0,
    weight_decay: float = 0.0,
    seed: int = 0,
    error_dtype=jnp.float32,
) -> Transform:
    """EF-SGD (Alg. 2) / EF-SIGNSGD (Alg. 1, the default compressor).

    With ``momentum>0`` this is the 'momentum correction' flavor (Lin et al.
    '18): EF wraps SGDM's update stream rather than vanilla SGD's.
    """
    comp = compressor if compressor is not None else ScaledSignCompressor()
    parts = [add_weight_decay(weight_decay)]
    if momentum:
        parts.append(trace(momentum))
    parts.append(scale_by_neg_lr(lr))
    parts.append(ef_transform(comp, seed=seed, error_dtype=error_dtype))
    return chain(*parts)


def apply_updates(params, updates):
    return jax.tree.map(lambda x, u: (x + u.astype(x.dtype)) if x is not None else None, params, updates)


def get_optimizer(name: str, lr, **kw) -> Transform:
    table: dict[str, Callable[..., Transform]] = {
        "sgd": sgd,
        "sgdm": lambda lr, **k: sgd(lr, momentum=k.pop("momentum", 0.9), **k),
        "signsgd": signsgd,
        "signum": signum,
        "adam": adam,
        "ef_sgd": ef_sgd,
        "ef_signsgd": ef_sgd,
        "ef_sgdm": lambda lr, **k: ef_sgd(lr, momentum=k.pop("momentum", 0.9), **k),
    }
    if name not in table:
        raise ValueError(f"unknown optimizer {name!r}; options: {sorted(table)}")
    return table[name](lr, **kw)
