"""Trace spans: name the exchange phases for XProf/perfetto and host timers.

Two kinds of region markers, matching the two kinds of time in a step:

* :func:`span` — in-graph. ``jax.named_scope`` attaches the span name to the
  op metadata of everything traced under it, so compiled-HLO ops (and the
  XProf timeline rows XLA derives from them) segment by exchange phase:
  ``obs.backward`` → ``obs.compress`` → ``obs.collective.<backend>`` →
  ``obs.decode`` → ``obs.apply``. Metadata only — applied unconditionally
  because it cannot change numerics (the bitwise tests run with it on).
* :func:`host_span` / :class:`WallTimers` — host-side. Wraps non-jit regions
  (dispatch, blocking on results, checkpoint writes) in
  ``jax.profiler.TraceAnnotation`` so they land on the profiler timeline too,
  and accumulates wall seconds for the JSONL run records.
"""

from __future__ import annotations

import time
from contextlib import contextmanager

import jax
import jax.profiler

#: canonical span names, in step order — tests and the README table key on
#: these exact strings appearing in compiled HLO ``op_name`` metadata
SPAN_BACKWARD = "obs.backward"
SPAN_BUCKETIZE = "obs.bucketize"
SPAN_COMPRESS = "obs.compress"
SPAN_COLLECTIVE = "obs.collective"  # suffixed ".<backend>" per transport
SPAN_DECODE = "obs.decode"
SPAN_APPLY = "obs.apply"

SPAN_NAMES = (
    SPAN_BACKWARD,
    SPAN_BUCKETIZE,
    SPAN_COMPRESS,
    SPAN_COLLECTIVE,
    SPAN_DECODE,
    SPAN_APPLY,
)


def span(name: str):
    """In-graph span: a ``jax.named_scope`` carrying an ``obs.`` name.

    ``name`` may be a bare phase (``"compress"``) or already qualified
    (``"collective.ring"``); either way the scope is ``obs.``-prefixed so
    profiler rows from this subsystem sort together.
    """
    if not name.startswith("obs."):
        name = f"obs.{name}"
    return jax.named_scope(name)


@contextmanager
def host_span(name: str):
    """Host-side region on the profiler timeline (non-jit work)."""
    if not name.startswith("obs."):
        name = f"obs.{name}"
    with jax.profiler.TraceAnnotation(name):
        yield


def step_span(step: int):
    """Whole-step marker; XProf's step-time view groups by these."""
    return jax.profiler.StepTraceAnnotation("train", step_num=step)


class WallTimers:
    """Named wall-clock accumulators for the host side of a step.

    ``with timers.region("step"): ...`` both annotates the profiler timeline
    and adds the elapsed seconds to ``timers.seconds["step"]``; ``drain()``
    returns and resets the totals, which is what the train loop folds into
    each JSONL record as ``wall_<name>_s``.
    """

    def __init__(self):
        self.seconds: dict[str, float] = {}

    @contextmanager
    def region(self, name: str):
        t0 = time.perf_counter()
        with host_span(name):
            yield
        self.seconds[name] = self.seconds.get(name, 0.0) + (time.perf_counter() - t0)

    def drain(self) -> dict[str, float]:
        out, self.seconds = self.seconds, {}
        return out
