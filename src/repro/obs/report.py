"""``python -m repro.obs report run.jsonl`` — summarize a run record file.

Reads a schema-v1 JSONL run (see :mod:`repro.obs.sink`), then reports:

* loss trajectory (first/last logged step),
* wire accounting — and whether the recorded per-step bytes match the
  analytic model the run_meta declared (they must, exactly: the in-graph
  counter and :func:`repro.obs.telemetry.modeled_wire_bytes` implement the
  same sum),
* density drift and EF-residual growth over the run,
* comm exposure under the proportional-split pipeline model when the
  records carry per-group bytes and wall timers,
* anomaly flags: residual-norm blow-up (the undeclared-Byzantine signature
  — 1901.09847 predicts bounded ``||e_t||`` under honest workers),
  density collapse/drift, wire-model mismatch, and robust-decode lanes
  drawing persistent filtering suspicion.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any

from repro.obs.sink import read_run

# anomaly thresholds (heuristic, documented in the README table)
RESIDUAL_BLOWUP_RATIO = 10.0  # late-run mean / early-run mean
DENSITY_DRIFT_RATIO = 0.5  # late-run mean below half the early-run mean
SUSPECT_LANE_FRAC = 0.5  # lane filtered in more than half its combines


def _mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else float("nan")


def _halves(series: list[float]) -> tuple[float, float]:
    """(early mean, late mean) over the first/last half of a series."""
    if not series:
        return float("nan"), float("nan")
    mid = max(1, len(series) // 2)
    return _mean(series[:mid]), _mean(series[mid:])


def summarize(records: list[dict[str, Any]]) -> dict[str, Any]:
    """Reduce a parsed run to the report dict (pure; rendering separate)."""
    meta = next((r for r in records if r.get("kind") == "run_meta"), None)
    steps = [r for r in records if r.get("kind") == "step"]
    final = next((r for r in records if r.get("kind") == "final"), None)

    out: dict[str, Any] = {
        "n_step_records": len(steps),
        "config": (meta or {}).get("config", {}),
        "telemetry": (meta or {}).get("telemetry", "off"),
        "final_loss": (final or {}).get("final_loss"),
        "anomalies": [],
    }

    losses = [r["loss"] for r in steps if "loss" in r]
    if losses:
        out["loss"] = {"first": losses[0], "last": losses[-1]}
        if not all(math.isfinite(x) for x in losses):
            out["anomalies"].append("nonfinite_loss")

    # --- wire accounting vs the declared analytic model (exact match) -----
    wires = [r["wire_bytes"] for r in steps if "wire_bytes" in r]
    if wires:
        out["wire_bytes_per_step"] = wires[-1]
        modeled = (meta or {}).get("modeled_wire_bytes")
        if modeled is not None:
            out["modeled_wire_bytes"] = modeled
            if any(wb != modeled for wb in wires):
                out["anomalies"].append("wire_model_mismatch")

    # --- density drift ----------------------------------------------------
    dens = [r["density"] for r in steps if "density" in r]
    if dens:
        early, late = _halves(dens)
        out["density"] = {"first": dens[0], "last": dens[-1], "early": early, "late": late}
        if any(not (0.0 <= d <= 1.0) for d in dens):
            out["anomalies"].append("density_out_of_unit")
        elif late < early * DENSITY_DRIFT_RATIO:
            out["anomalies"].append("density_drift")

    # --- EF-residual growth (telemetry="full" runs only) ------------------
    res = [sum(r["err_l2"]) for r in steps if "err_l2" in r]
    if res:
        early, late = _halves(res)
        out["err_l2"] = {"first": res[0], "last": res[-1], "early": early, "late": late}
        if not all(math.isfinite(x) for x in res):
            out["anomalies"].append("residual_nonfinite")
        elif early > 0 and late > early * RESIDUAL_BLOWUP_RATIO:
            out["anomalies"].append("residual_blowup")

    # --- robust-decode lane suspicion -------------------------------------
    lane_runs = [r["filtered_lanes"] for r in steps if "filtered_lanes" in r]
    if lane_runs and any(any(x > 0 for x in lanes) for lanes in lane_runs):
        totals = [sum(col) for col in zip(*lane_runs)]
        out["filtered_lane_totals"] = totals
        denom = sum(totals)
        suspects = [
            i for i, t in enumerate(totals) if denom and t / denom > SUSPECT_LANE_FRAC
        ]
        if suspects:
            out["suspect_lanes"] = suspects
            out["anomalies"].append("suspect_lanes")

    # --- comm exposure under the proportional pipeline model --------------
    gb = next((r["group_bytes"] for r in reversed(steps) if "group_bytes" in r), None)
    wall = next((r.get("wall_step_s") for r in reversed(steps) if "wall_step_s" in r), None)
    if gb and len(gb) > 1 and sum(gb) > 0 and wall:
        from repro.overlap.pipeline import proportional_exposure  # lazy: heavy deps

        from repro.core.aggregation import REF_WIRE_BYTES_PER_US

        serial_us = sum(gb) / REF_WIRE_BYTES_PER_US
        out["comm_exposure"] = proportional_exposure(gb, wall * 1e6, serial_us)

    return out


def format_summary(summary: dict[str, Any]) -> str:
    """Human-readable rendering of :func:`summarize`."""
    lines = []
    cfg = summary.get("config", {})
    head = " ".join(f"{k}={cfg[k]}" for k in sorted(cfg)) or "(no run_meta)"
    lines.append(f"run: {head}")
    lines.append(
        f"telemetry={summary['telemetry']} step_records={summary['n_step_records']}"
    )
    if "loss" in summary:
        ls = summary["loss"]
        fl = summary.get("final_loss")
        lines.append(
            f"loss: first={ls['first']:.4f} last={ls['last']:.4f}"
            + (f" final={fl:.4f}" if fl is not None else "")
        )
    if "wire_bytes_per_step" in summary:
        line = f"wire: {summary['wire_bytes_per_step']:.0f} B/step/device"
        if "modeled_wire_bytes" in summary:
            ok = "wire_model_mismatch" not in summary["anomalies"]
            line += f" (model {summary['modeled_wire_bytes']:.0f} B — {'match' if ok else 'MISMATCH'})"
        lines.append(line)
    if "density" in summary:
        d = summary["density"]
        lines.append(f"density: first={d['first']:.4f} last={d['last']:.4f}")
    if "err_l2" in summary:
        e = summary["err_l2"]
        lines.append(
            f"ef-residual L2: first={e['first']:.4g} last={e['last']:.4g} "
            f"(early-half mean {e['early']:.4g} → late-half mean {e['late']:.4g})"
        )
    if "filtered_lane_totals" in summary:
        tot = ", ".join(f"{t:.2f}" for t in summary["filtered_lane_totals"])
        lines.append(f"robust filtering per lane: [{tot}]")
    if "comm_exposure" in summary:
        ex = summary["comm_exposure"]
        lines.append(
            f"comm exposure (proportional model): {ex['exposure_frac']:.1%} of "
            f"{ex['serial_comm_us']:.0f} us serial bill exposed"
        )
    anomalies = summary.get("anomalies", [])
    lines.append("anomalies: " + (", ".join(anomalies) if anomalies else "none"))
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs report", description="summarize a run.jsonl"
    )
    ap.add_argument("path", help="run record file written via --log-dir")
    ap.add_argument("--json", action="store_true", help="emit the summary as JSON")
    args = ap.parse_args(argv)
    summary = summarize(read_run(args.path))
    if args.json:
        print(json.dumps(summary))
    else:
        print(format_summary(summary))
    # anomalies are informational, not a failure — exit 0 either way so the
    # CLI composes into pipelines that inspect the JSON
    return 0


if __name__ == "__main__":
    sys.exit(main())
