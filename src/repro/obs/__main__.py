"""``python -m repro.obs <subcommand>`` — observability CLI.

Currently one subcommand: ``report run.jsonl`` (see :mod:`repro.obs.report`).
"""

from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs report <run.jsonl> [--json]")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        from repro.obs.report import main as report_main

        return report_main(rest)
    print(f"unknown subcommand {cmd!r}; expected 'report'", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
