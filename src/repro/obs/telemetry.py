"""In-graph telemetry: the :class:`Telemetry` pytree and its metric reducers.

The paper's central quantities — the EF residual ``e_t`` that absorbs
compression error (Karimireddy et al., 1901.09847), the sign-compression
density φ, and the bytes the wire actually moves — are all values the
bucketed aggregator *already materializes* while it runs. ``Telemetry`` is a
pure read of those intermediates, returned as an aux output of the
aggregator (``AggInfo.telemetry``) behind ``CommSpec.telemetry``:

``off``   the field is ``None`` — an EMPTY pytree, so the aggregator's
          output structure carries zero extra leaves and the compiled
          program is exactly today's (the bitwise-invariance tests pin it).
``full``  one fixed-shape :class:`Telemetry` per step.

The shape of every field is static per spec, which is what lets
``train/steps.py`` thread it through ``jit`` out-shardings unchanged from
step to step and the JSONL sink (:mod:`repro.obs.sink`) write schema-stable
records.

This module deliberately imports nothing from :mod:`repro.comm` at module
scope — ``comm.collective`` imports it for the reducers, so the wire-model
helpers defer their strategy-table lookups to call time.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compressors import Compressor, ScaledSignCompressor

#: accepted values of ``CommSpec.telemetry``
TELEMETRY_CHOICES = ("off", "full")


class Telemetry(NamedTuple):
    """Per-step in-graph telemetry of one gradient exchange.

    Every leaf is replicated across the mesh (out-spec ``P()``); worker-local
    quantities (residual norms, densities) are ``pmean``\\ ed over the EF axes
    inside the aggregator so the record is one number per group, not per
    worker.
    """

    err_l2: jax.Array  # (n_dtype_groups,) f32 — EF-residual L2 per group
    density: jax.Array  # (n_dtype_groups,) f32 — compressed density φ per group
    wire_bytes: jax.Array  # () f32 — bytes this device received this step
    group_bytes: jax.Array  # (n_units,) f32 — wire split per exchange unit
    filtered_lanes: jax.Array  # (world,) f32 — robust-decode drop weight per lane


#: the schema behind every ``Telemetry`` instance and its JSONL spelling —
#: rendered by ``launch/dryrun.py`` and the README's Observability table
TELEMETRY_FIELDS = (
    {
        "name": "err_l2",
        "shape": "(n_dtype_groups,)",
        "unit": "l2-norm",
        "doc": "EF-residual L2 per dtype bucket group, pmean over EF workers "
        "(the paper's bounded ||e_t||; blow-up flags a diverging exchange)",
    },
    {
        "name": "density",
        "shape": "(n_dtype_groups,)",
        "unit": "fraction",
        "doc": "compressed density φ(p) per dtype bucket group from the fused "
        "bucket-stats pass (Lemma 8 quality), pmean over EF workers",
    },
    {
        "name": "wire_bytes",
        "shape": "()",
        "unit": "bytes",
        "doc": "bytes received per device this step — equals the analytic "
        "model in core.aggregation exactly (the report CLI cross-checks)",
    },
    {
        "name": "group_bytes",
        "shape": "(n_units,)",
        "unit": "bytes",
        "doc": "wire_bytes split per exchange unit: per dtype group on the "
        "one-shot path, per schedule group on the overlap pipeline (feeds "
        "the comm-exposure model)",
    },
    {
        "name": "filtered_lanes",
        "shape": "(world,)",
        "unit": "combines",
        "doc": "robust-decode drop weight per EF-worker lane, summed over "
        "this step's combines (norm-filter: 0/1 per group; trimmed-mean: "
        "fraction of coordinates trimmed; zeros when not filtering)",
    },
)


def telemetry_schema() -> tuple[dict, ...]:
    """The field table every ``telemetry="full"`` record follows."""
    return TELEMETRY_FIELDS


def replicated_specs() -> Telemetry:
    """``shard_map``/``jit`` out-spec tree: every telemetry leaf replicated."""
    return Telemetry(P(), P(), P(), P(), P())


def residual_l2(err: jax.Array) -> jax.Array:
    """Scalar L2 norm of one group's EF residual — finite, >= 0."""
    return jnp.sqrt(jnp.sum(jnp.square(err.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# analytic wire models (must mirror the in-graph accounting exactly)
# ---------------------------------------------------------------------------


def modeled_wire_bytes(
    strategy: str, layout, world: int, comp: Compressor | None = None
) -> float:
    """Bytes per device per step the aggregator will bill for ``strategy``.

    Mirrors the in-graph accounting of ``comm.collective`` term for term —
    per dtype group, with ``ef_alltoall``'s per-group ceil-divided server
    shards (a sum of ceils, NOT a ceil of the sum) — so a run record's
    ``wire_bytes`` matches this number *exactly*, which the report CLI and
    the property tests both gate. For the sign family this reduces to the
    closed forms in :mod:`repro.core.aggregation`.
    """
    from repro.comm import collective, compressed  # deferred: collective imports us

    if strategy not in collective.STRATEGIES:
        raise ValueError(
            f"unknown bucketed strategy {strategy!r}; options: {collective.STRATEGIES}"
        )
    comp = comp or ScaledSignCompressor()
    bs = layout.bucket_size
    bucket_bits = comp.wire_bits(bs)
    bits = 0.0
    for g in layout.groups:
        nb = g.n_buckets
        if strategy == "dense":
            bits += 2 * 32 * nb * bs  # fp32 ring all-reduce model
        elif strategy == "majority_vote":
            bits += (world - 1) * nb * bs  # d bits per peer payload
        elif strategy == "ef_alltoall":
            nbw = compressed.server_shard_buckets(nb, world)
            bits += 2 * (world - 1) * nbw * bucket_bits
        else:  # mean family + the robust variants: identical wire bill
            bits += (world - 1) * nb * bucket_bits
    return bits / 8.0


def modeled_fed_wire_bytes(layout, cohort: int, comp: Compressor | None = None) -> float:
    """Bytes the federated server receives per round: ``cohort`` bucket
    payloads per group — only sampled clients pay, independent of the client
    population. Mirrors the in-graph accounting of ``repro.fed.round`` term
    for term (for the sign family this reduces to
    ``core.aggregation.fed_round_wire_bytes`` summed over dtype groups)."""
    comp = comp or ScaledSignCompressor()
    bits = sum(cohort * g.n_buckets * comp.wire_bits(layout.bucket_size) for g in layout.groups)
    return bits / 8.0


def strategy_wire_models(
    layout, world: int, comp: Compressor | None = None
) -> dict[str, float]:
    """``{strategy: modeled bytes/step/device}`` for every bucketed strategy
    — what ``launch/dryrun.py`` prints alongside the spec dump."""
    from repro.comm import collective  # deferred: collective imports us

    return {
        s: modeled_wire_bytes(s, layout, world, comp) for s in collective.STRATEGIES
    }


def to_host(t: Telemetry) -> dict[str, Any]:
    """Pull one step's telemetry off-device into JSON-serializable fields.

    The one place traced telemetry crosses to host records (the counterpart
    of ``core.aggregation.info_dict`` for the extended schema).
    """
    import numpy as np

    return {
        "err_l2": [float(x) for x in np.asarray(t.err_l2)],
        "group_density": [float(x) for x in np.asarray(t.density)],
        "group_bytes": [float(x) for x in np.asarray(t.group_bytes)],
        "filtered_lanes": [float(x) for x in np.asarray(t.filtered_lanes)],
        "telemetry_wire_bytes": float(t.wire_bytes),
    }
