"""Schema-versioned JSONL run records.

One training run → one ``run.jsonl``: a ``run_meta`` line, one ``step``
line per logged step, and a ``final`` line emitted unconditionally (even
for zero-step runs — the ``history[-1]`` epilogue crash this replaces).
Records are plain JSON objects with a ``kind`` discriminator and a
``schema`` version so ``repro.obs report`` (and anything downstream) can
refuse files it does not understand instead of misreading them.

Schema v1:

``run_meta``  schema, kind, config {strategy, backend, world, steps, ...},
              telemetry level, modeled wire bytes (when bucketed), and the
              telemetry field table from :mod:`repro.obs.telemetry`.
``step``      step, loss, wire_bytes, density, wall-clock regions, plus the
              flattened :class:`Telemetry` fields when the level is "full".
``final``     steps completed, final_loss (null when no steps ran),
              total wall seconds.
"""

from __future__ import annotations

import json
import os
from typing import Any, TextIO

SCHEMA_VERSION = 1


def run_meta(
    *,
    config: dict[str, Any],
    telemetry: str,
    modeled_wire_bytes: float | None = None,
    wire_models: dict[str, float] | None = None,
) -> dict[str, Any]:
    """The run-header record: what this run is and what it will log."""
    from repro.obs.telemetry import telemetry_schema

    rec: dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "kind": "run_meta",
        "telemetry": telemetry,
        "config": dict(config),
    }
    if modeled_wire_bytes is not None:
        rec["modeled_wire_bytes"] = float(modeled_wire_bytes)
    if wire_models is not None:
        rec["wire_models"] = {k: float(v) for k, v in wire_models.items()}
    if telemetry != "off":
        rec["telemetry_fields"] = list(telemetry_schema())
    return rec


def step_record(
    step: int,
    metrics: dict[str, Any],
    *,
    walls: dict[str, float] | None = None,
) -> dict[str, Any]:
    """One logged step. ``metrics`` is the host-side metrics dict from the
    train step (``loss``/``wire_bytes``/``density`` floats, plus an ``obs``
    :class:`~repro.obs.telemetry.Telemetry` when the level is "full", which
    is flattened into scalar-list fields here)."""
    from repro.obs.telemetry import to_host

    rec: dict[str, Any] = {"schema": SCHEMA_VERSION, "kind": "step", "step": int(step)}
    for k, v in metrics.items():
        if k == "obs":
            if v is not None:
                rec.update(to_host(v))
        else:
            rec[k] = float(v)
    for name, s in (walls or {}).items():
        rec[f"wall_{name}_s"] = float(s)
    return rec


def final_record(
    history: list[dict[str, Any]],
    *,
    steps: int,
    wall_s: float | None = None,
) -> dict[str, Any]:
    """The unconditional run epilogue. ``final_loss`` is read from the last
    history record when one exists and is ``None`` otherwise — callers print
    from this record instead of indexing ``history[-1]`` (which raises
    IndexError on zero-step runs)."""
    last = history[-1] if history else None
    rec: dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "kind": "final",
        "steps": int(steps),
        "final_loss": (float(last["loss"]) if last and "loss" in last else None),
    }
    if last and "step" in last:
        rec["last_logged_step"] = int(last["step"])
    if wall_s is not None:
        rec["wall_s"] = float(wall_s)
    return rec


class RunRecordWriter:
    """Append-only JSONL writer; one line per record, flushed per write so a
    crashed run still leaves a readable prefix."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._fh: TextIO | None = open(path, "w")

    def write(self, record: dict[str, Any]) -> None:
        if self._fh is None:
            raise ValueError(f"writer for {self.path} is closed")
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "RunRecordWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_run(path: str) -> list[dict[str, Any]]:
    """Parse a run.jsonl, validating the schema version of every record."""
    records = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            ver = rec.get("schema")
            if ver != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{ln}: schema {ver!r} (this reader understands "
                    f"{SCHEMA_VERSION}) — regenerate the run or upgrade repro"
                )
            records.append(rec)
    return records
