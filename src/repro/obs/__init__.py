"""repro.obs — observability for every gradient exchange.

Three layers: in-graph :class:`~repro.obs.telemetry.Telemetry` (aux output
of the aggregators behind ``CommSpec.telemetry``), trace spans
(:mod:`repro.obs.trace`), and JSONL run records + report CLI
(:mod:`repro.obs.sink`, :mod:`repro.obs.report`,
``python -m repro.obs report``).

Only the jax-only layers are imported eagerly — ``repro.comm.collective``
imports this package at module scope, so pulling sink/report (which reach
back into comm/overlap) here would create a cycle.
"""

from repro.obs.telemetry import (
    TELEMETRY_CHOICES,
    Telemetry,
    modeled_wire_bytes,
    replicated_specs,
    residual_l2,
    strategy_wire_models,
    telemetry_schema,
)
from repro.obs.trace import SPAN_NAMES, WallTimers, host_span, span, step_span

__all__ = [
    "TELEMETRY_CHOICES",
    "Telemetry",
    "modeled_wire_bytes",
    "replicated_specs",
    "residual_l2",
    "strategy_wire_models",
    "telemetry_schema",
    "SPAN_NAMES",
    "WallTimers",
    "host_span",
    "span",
    "step_span",
]
