"""`FedSpec` — the declarative description of one federated simulation.

The federated tier rides the bucketed EF wire format as a `CommSpec` rider
(``CommSpec.fed``): strategy/compressor/bucket_size keep their meaning (what
each sampled client ships), and this spec adds the population knobs — how
many simulated clients exist, how many are sampled per round, how their
shards are skewed, and how stale cohorts fold in.

Every invalid combination raises :class:`repro.comm.errors.FedConfigError`
(a ``CommSpecError``, hence a ``ValueError``) at CONSTRUCTION time — in
particular a cohort that resolves to zero sampled clients, which would
otherwise NaN the weighted mean at runtime (0-row reductions). Both the
factory and the launcher flag path hit the same check.
"""

from __future__ import annotations

import dataclasses

from repro.comm.errors import FedConfigError

#: accepted ``FedSpec.weighting`` values — FedAvg dataset-size weights or a
#: plain cohort mean (the latter is also what statically-equal sizes reduce to)
WEIGHTINGS = ("dataset_size", "uniform")


@dataclasses.dataclass(frozen=True)
class FedSpec:
    """Population + sampling knobs of the federated tier.

    ``cohort`` (an absolute per-round client count) and ``participation`` (a
    sampled fraction of ``n_clients``) are two spellings of the same knob —
    setting both is rejected; setting neither means full participation.
    ``label_skew`` ∈ [0, 1] narrows each client's vocab window (non-IID label
    distribution over the synthetic token stream); ``size_skew`` ≥ 0 is the
    power-law exponent of the per-client dataset sizes (scale skew — it feeds
    the FedAvg weights). ``staleness`` D > 0 turns on the async-round mode:
    the applied update mixes the fresh cohort aggregate with the previous D
    rounds' aggregates, weighted ∝ 1/(1+d) (polynomial staleness discount).
    ``base_examples`` is the mean client dataset size the shard constructor
    scales to.
    """

    n_clients: int = 100
    cohort: int | None = None
    participation: float | None = None
    weighting: str = "dataset_size"
    label_skew: float = 0.0
    size_skew: float = 0.0
    staleness: int = 0
    base_examples: int = 32

    def __post_init__(self):
        if self.n_clients < 1:
            raise FedConfigError(f"fed n_clients must be >= 1, got {self.n_clients}")
        if self.cohort is not None and self.participation is not None:
            raise FedConfigError(
                "set either fed cohort (absolute) or participation (fraction), not both; "
                f"got cohort={self.cohort}, participation={self.participation}"
            )
        if self.participation is not None and not 0.0 < self.participation <= 1.0:
            raise FedConfigError(
                f"fed participation must be in (0, 1], got {self.participation}"
            )
        if self.cohort is not None and self.cohort > self.n_clients:
            raise FedConfigError(
                f"fed cohort {self.cohort} exceeds n_clients {self.n_clients}"
            )
        # the zero-sampled-cohort edge: reject at spec validation, not as a
        # NaN'd weighted mean at runtime (cohort=0 directly, or a fraction
        # that floors to 0 clients)
        if self.cohort_size < 1:
            how = (
                f"cohort={self.cohort}"
                if self.cohort is not None
                else f"participation={self.participation} of n_clients={self.n_clients} "
                f"rounds to {self.cohort_size}"
            )
            raise FedConfigError(
                f"fed round would sample 0 clients ({how}); a round needs at "
                "least one participant"
            )
        if self.weighting not in WEIGHTINGS:
            raise FedConfigError(
                f"unknown fed weighting {self.weighting!r}; options: {WEIGHTINGS}"
            )
        if not 0.0 <= self.label_skew <= 1.0:
            raise FedConfigError(f"fed label_skew must be in [0, 1], got {self.label_skew}")
        if self.size_skew < 0.0:
            raise FedConfigError(f"fed size_skew must be >= 0, got {self.size_skew}")
        if self.staleness < 0:
            raise FedConfigError(f"fed staleness must be >= 0, got {self.staleness}")
        if self.base_examples < 1:
            raise FedConfigError(f"fed base_examples must be >= 1, got {self.base_examples}")

    @property
    def cohort_size(self) -> int:
        """Resolved clients sampled per round (cohort wins; else
        ``floor(participation · n_clients)``; else full participation)."""
        if self.cohort is not None:
            return self.cohort
        if self.participation is not None:
            return int(self.participation * self.n_clients)
        return self.n_clients

    @property
    def full_participation(self) -> bool:
        return self.cohort_size == self.n_clients

    @staticmethod
    def from_args(
        clients: int | None,
        cohort: int | None,
        participation: float | None,
        shard_skew: float | None,
        size_skew: float | None = None,
        staleness: int | None = None,
    ) -> "FedSpec | None":
        """CLI plumbing: any ``--clients`` / ``--cohort`` / ``--participation``
        / ``--shard-skew`` / ``--size-skew`` / ``--fed-staleness`` flag
        switches the federated tier on; unset knobs keep defaults."""
        knobs = (clients, cohort, participation, shard_skew, size_skew, staleness)
        if all(k is None for k in knobs):
            return None
        kw = {}
        if clients is not None:
            kw["n_clients"] = clients
        if cohort is not None:
            kw["cohort"] = cohort
        if participation is not None:
            kw["participation"] = participation
        if shard_skew is not None:
            kw["label_skew"] = shard_skew
        if size_skew is not None:
            kw["size_skew"] = size_skew
        if staleness is not None:
            kw["staleness"] = staleness
        return FedSpec(**kw)
