"""repro.fed — federated EF simulation on the bucket wire format.

Million-client error-feedback simulation as vmap'd cohorts over the existing
``repro.comm`` bucket wire format: deterministic client sampling, FedAvg
dataset-size weighting, per-client EF residual pools that persist bitwise
across skipped rounds, non-IID shards, and an async staleness mode. Rides a
:class:`~repro.comm.api.CommSpec` via the ``fed`` rider
(:class:`~repro.fed.spec.FedSpec`).
"""

from repro.fed.round import FedState, init_fed_state, make_fed_round, staleness_weights
from repro.fed.sampling import dataset_weights, sample_cohort
from repro.fed.shards import client_sizes, make_client_data_fn
from repro.fed.spec import FedSpec

__all__ = [
    "FedSpec",
    "FedState",
    "client_sizes",
    "dataset_weights",
    "init_fed_state",
    "make_client_data_fn",
    "make_fed_round",
    "sample_cohort",
    "staleness_weights",
]
