"""Federated training loop: rounds instead of steps, same run records.

``run_training`` dispatches here when the job's ``CommSpec`` carries a
``fed`` rider. One round = one compiled call of
:func:`repro.fed.round.make_fed_round`; ``job.steps`` counts ROUNDS and
``job.batch`` is the PER-CLIENT batch (a cohort of C clients sees C·batch
sequences per round). The JSONL sink writes the same schema-versioned
records as the data-parallel loop — ``modeled_wire_bytes`` uses the fed wire
model (only sampled clients pay), and telemetry="full" threads the in-graph
:class:`~repro.obs.telemetry.Telemetry` through unchanged.
"""

from __future__ import annotations

import os
import time
from typing import Callable

import jax

from repro.comm import bucketize as comm_bucketize
from repro.comm.api import CommSpec
from repro.core.compressors import ScaledSignCompressor
from repro.fed import round as fed_round
from repro.fed import shards
from repro.models import transformer
from repro.models.act_sharding import activation_sharding
from repro.obs import sink as obs_sink
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace


def run_fed_training(job, spec: CommSpec | None = None, log_fn: Callable | None = None):
    """Run ``job.steps`` federated rounds; returns ``(FedState, history)``."""
    from repro.train import loop as train_loop  # runtime import; no cycle

    spec = spec or job.comm_spec()
    fed = spec.fed
    assert fed is not None, "run_fed_training needs a CommSpec with a fed rider"
    spec.validate()
    cfg = job.cfg
    chain = train_loop._local_chain(job)
    comp = spec.resolved_compressor or ScaledSignCompressor()
    key = jax.random.PRNGKey(job.seed)

    params = transformer.init_params(cfg, key)
    layout = comm_bucketize.build_layout(params, spec.bucket_size)
    sizes = shards.client_sizes(
        fed.n_clients, fed.size_skew, seed=job.seed, base=fed.base_examples
    )
    data_fn = shards.make_client_data_fn(
        fed, batch=job.batch, seq=job.seq, vocab=cfg.vocab_size
    )

    def grad_fn(p, b):
        def lf(pp):
            with activation_sharding(None, None):
                return transformer.loss_fn(pp, cfg, b)

        return jax.value_and_grad(lf, has_aux=True)(p)

    round_fn = fed_round.make_fed_round(
        fed, layout, comp, chain, grad_fn, data_fn,
        sizes=sizes, telemetry=spec.telemetry == "full",
    )
    state = fed_round.init_fed_state(params, chain, layout, fed, seed=job.seed)
    fn = jax.jit(round_fn, donate_argnums=(0,))

    writer = None
    if job.log_dir:
        writer = obs_sink.RunRecordWriter(os.path.join(job.log_dir, "run.jsonl"))
        writer.write(
            obs_sink.run_meta(
                config={
                    "strategy": spec.strategy,
                    "backend": spec.backend,
                    "steps": job.steps,
                    "batch": job.batch,
                    "seq": job.seq,
                    "optimizer": job.optimizer,
                    "bucket_size": spec.bucket_size,
                    "fed_clients": fed.n_clients,
                    "fed_cohort": fed.cohort_size,
                    "fed_label_skew": fed.label_skew,
                    "fed_size_skew": fed.size_skew,
                    "fed_staleness": fed.staleness,
                },
                telemetry=spec.telemetry,
                modeled_wire_bytes=obs_telemetry.modeled_fed_wire_bytes(
                    layout, fed.cohort_size, comp
                ),
            )
        )

    history = []
    timers = obs_trace.WallTimers()
    t0 = time.time()
    try:
        for i in range(job.steps):
            logged = i % job.log_every == 0 or i == job.steps - 1
            with obs_trace.step_span(i), timers.region("step"):
                state, (loss, metrics) = fn(state)
                if logged:
                    jax.block_until_ready(loss)
            walls = timers.drain()
            if logged:
                rec = obs_sink.step_record(i, {"loss": loss, **metrics}, walls=walls)
                rec["wall_s"] = time.time() - t0
                history.append(rec)
                if log_fn:
                    log_fn(rec)
                if writer:
                    writer.write(rec)
    finally:
        if writer:
            writer.write(
                obs_sink.final_record(history, steps=job.steps, wall_s=time.time() - t0)
            )
            writer.close()
    return state, history
