"""Deterministic client sampling and FedAvg dataset-size weights.

The cohort for round *t* is a pure function of the carried run key: the
round function splits its key exactly like the data-parallel train step
(``key, sub = jax.random.split(state.key)``) and folds a sampling tag into
``sub`` — so resuming a run from round *t* replays the same cohorts, and the
full-participation short-circuit (no sampling op at all) keeps the compiled
program identical to the data-parallel step (the bitwise pin).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: fold_in tags carving independent streams out of the per-round subkey
#: (mirrors the byz injector's 0x5A1 idiom — the honest stream is untouched)
SAMPLE_TAG = 0xFED5
DATA_TAG = 0xFEDD


def sample_cohort(key: jax.Array, n_clients: int, cohort: int) -> jax.Array:
    """Sample ``cohort`` distinct client ids out of ``n_clients``.

    Without replacement, ascending order — sorted ids make the residual-pool
    gather/scatter order deterministic and the cohort easy to eyeball in run
    records. (jax draws via an O(n) permutation; at n=10^6 that is a 4 MB
    scratch array, fine for the simulation tier.)
    """
    idx = jax.random.choice(key, n_clients, shape=(cohort,), replace=False)
    return jnp.sort(idx).astype(jnp.int32)


def dataset_weights(sizes: jax.Array) -> jax.Array:
    """FedAvg weights of one cohort: sizes normalized to sum to 1 (f32).

    Permutation-equivariant by construction — permuting the cohort permutes
    the weights identically (the property tests pin this along with the
    sum-to-1 invariant).
    """
    s = sizes.astype(jnp.float32)
    return s / jnp.sum(s)
