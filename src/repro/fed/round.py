"""The federated round: one compiled program per cohort.

A round is sample → shard batches → per-client grads + local chain →
bucketize → EF-encode against each client's OWN residual row → weighted
server combine → (optional staleness mix) → apply — all inside one ``jit``,
with the cohort as a leading ``vmap`` axis. 10^4+ simulated clients is one
compile; nothing in the program scales with ``n_clients`` except the
residual-pool gather/scatter and the O(n) sampling permutation.

Program-identity short-circuits (the bitwise pins depend on these, the same
way ``byz_f=0`` short-circuits to the literal mean decode):

* full participation: no sampling op, no gather/scatter — the pool IS the
  stacked residual, exactly the data-parallel step's ``worker_error``;
* statically-uniform weights: the combine is the literal
  ``decode_mean_buckets``;
* ``staleness=0``: no history buffer in the state, no mixing ops.

RNG mirrors the data-parallel step: ``key, sub = jax.random.split(state.key)``
once per round; sampling/data/compressor streams are tagged ``fold_in``\\ s of
``sub`` (dead code for deterministic compressors and fixed-batch drivers).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import bucketize, compressed
from repro.core import optim
from repro.core.compressors import Compressor, ScaledSignCompressor
from repro.fed import sampling, server
from repro.fed.spec import FedSpec
from repro.obs import telemetry as obs_telemetry


class FedState(NamedTuple):
    """Carried state of the federated simulation.

    ``residuals`` is the per-client EF memory: one ``(n_clients, n_buckets,
    bucket_size)`` f32 pool per dtype group — rows of non-participating
    clients are carried bitwise across rounds (the paper's guarantee under
    partial participation). Clients are otherwise STATELESS (FedAvg style):
    ``opt_state`` is the one shared local-chain state every sampled client
    applies, advanced once per round. ``stale`` is the async-mode ring of the
    previous D rounds' aggregates (newest first), ``()`` when staleness=0.
    """

    params: Any
    opt_state: Any
    residuals: tuple[jax.Array, ...]
    stale: tuple[jax.Array, ...]
    key: jax.Array
    round: jax.Array


def init_fed_state(
    params: Any,
    chain: optim.Transform,
    layout: bucketize.BucketLayout,
    spec: FedSpec,
    *,
    seed: int = 0,
) -> FedState:
    """Zero residual pools / staleness ring, fresh chain state and run key.

    Pool memory is ``4 · n_clients · padded_elements`` bytes — million-client
    simulations want a small model or a coarse layout (the fed bench runs
    10^6 clients over a one-bucket toy problem).
    """
    pool = tuple(
        jnp.zeros((spec.n_clients, g.n_buckets, layout.bucket_size), jnp.float32)
        for g in layout.groups
    )
    stale = tuple(
        jnp.zeros((spec.staleness, g.n_buckets, layout.bucket_size), jnp.float32)
        for g in layout.groups
    ) if spec.staleness else ()
    return FedState(
        params=params,
        opt_state=chain.init(params),
        residuals=pool,
        stale=stale,
        key=jax.random.PRNGKey(seed),
        round=jnp.int32(0),
    )


def staleness_weights(d: int) -> np.ndarray:
    """Polynomial staleness discount over ages 0..d: ``α_a ∝ 1/(1+a)``,
    normalized — the FedAsync-style mixing the async-round mode applies."""
    a = 1.0 / (1.0 + np.arange(d + 1, dtype=np.float64))
    return a / a.sum()


def make_fed_round(
    spec: FedSpec,
    layout: bucketize.BucketLayout,
    comp: Compressor | None,
    chain: optim.Transform,
    grad_fn: Callable,
    data_fn: Callable,
    *,
    sizes: np.ndarray | None = None,
    telemetry: bool = False,
) -> Callable[[FedState], tuple[FedState, tuple[jax.Array, dict]]]:
    """Build ``round_fn(state) -> (new_state, (loss, metrics))``.

    ``grad_fn(params, batch) -> ((loss, metrics), grads)`` is the train-step
    convention; ``data_fn(idx, key, round) -> batches`` returns the cohort's
    stacked batches (leading axis = cohort — at full participation ``idx`` is
    statically ``arange`` and a driver may ignore it). ``sizes`` is the
    static (n_clients,) dataset-size vector feeding the FedAvg weights;
    ``None`` (or all-equal sizes, or ``weighting="uniform"``) selects the
    uniform-mean fast path. Metrics carry ``wire_bytes`` (what the server
    receives — only the sampled cohort pays) and ``density``, plus a
    ``Telemetry`` under ``"obs"`` when ``telemetry=True`` (pure reads; the
    off-mode program is bitwise-unchanged).
    """
    comp = comp or ScaledSignCompressor()
    n, c = spec.n_clients, spec.cohort_size
    full = spec.full_participation
    bs = layout.bucket_size
    masks = tuple(bucketize.valid_mask(layout, gi) for gi in range(len(layout.groups)))
    bucket_bits = comp.wire_bits(bs)
    if sizes is None:
        sizes = np.full(n, spec.base_examples, dtype=np.int64)
    sizes = np.asarray(sizes)
    if sizes.shape != (n,):
        raise ValueError(f"sizes must have shape ({n},), got {sizes.shape}")
    if (sizes < 1).any():
        raise ValueError("every client dataset size must be >= 1")
    uniform = spec.weighting == "uniform" or bool(np.all(sizes == sizes[0]))
    sizes_dev = None if uniform else jnp.asarray(sizes, jnp.float32)
    d_stale = spec.staleness
    alphas = staleness_weights(d_stale) if d_stale else None
    # only sampled clients pay bytes: the server receives c payloads per
    # bucket per round, regardless of n_clients
    grp_bits = [c * g.n_buckets * bucket_bits for g in layout.groups]
    wire_bits = float(sum(grp_bits))

    def round_fn(state: FedState):
        params = state.params
        key, sub = jax.random.split(state.key)
        if full:
            idx = jnp.arange(n, dtype=jnp.int32)
        else:
            idx = sampling.sample_cohort(
                jax.random.fold_in(sub, sampling.SAMPLE_TAG), n, c
            )
        batches = data_fn(idx, jax.random.fold_in(sub, sampling.DATA_TAG), state.round)
        (loss_c, metrics_c), grads_c = jax.vmap(lambda b: grad_fn(params, b))(batches)
        updates_c, opt_c = jax.vmap(
            lambda g: chain.update(g, state.opt_state, params)
        )(grads_c)
        new_opt = jax.tree.map(lambda x: x[0], opt_c)
        buckets_c = jax.vmap(lambda u: bucketize.flatten_buckets(layout, u))(updates_c)
        res_c = state.residuals if full else server.gather_rows(state.residuals, idx)
        weights = None
        if not uniform:
            weights = sampling.dataset_weights(sizes_dev[idx])

        outs, new_res, dens, err_norms = [], [], [], []
        for gi in range(len(layout.groups)):
            if comp.deterministic:
                payload_c, ne_c, d_c = jax.vmap(
                    lambda bk, e, gi=gi: compressed.ef_encode_buckets(
                        comp, bk, e, mask=masks[gi]
                    )
                )(buckets_c[gi], res_c[gi])
            else:
                gkeys = jax.vmap(
                    lambda cid, gi=gi: jax.random.fold_in(jax.random.fold_in(sub, cid), gi)
                )(idx)
                payload_c, ne_c, d_c = jax.vmap(
                    lambda bk, e, k, gi=gi: compressed.ef_encode_buckets(
                        comp, bk, e, mask=masks[gi], key=k
                    )
                )(buckets_c[gi], res_c[gi], gkeys)
            outs.append(server.weighted_combine(comp, payload_c, bs, weights))
            new_res.append(ne_c)
            dens.append(jnp.mean(d_c))
            if telemetry:
                err_norms.append(jnp.mean(jax.vmap(obs_telemetry.residual_l2)(ne_c)))

        if d_stale:
            mixed, new_stale = [], []
            for gi, fresh in enumerate(outs):
                hist = state.stale[gi]  # (D, nb, bs), newest first
                mix = jnp.float32(alphas[0]) * fresh + jnp.tensordot(
                    jnp.asarray(alphas[1:], jnp.float32), hist, axes=1
                )
                mixed.append(mix)
                new_stale.append(jnp.concatenate([fresh[None], hist[:-1]], axis=0))
            applied, stale = mixed, tuple(new_stale)
        else:
            applied, stale = outs, ()

        updates = bucketize.unflatten_buckets(layout, tuple(applied))
        params = optim.apply_updates(params, updates)
        pool = (
            tuple(new_res)
            if full
            else server.scatter_rows(state.residuals, idx, tuple(new_res))
        )

        loss = jnp.mean(loss_c)
        metrics = {k: jnp.mean(v) for k, v in metrics_c.items()}
        metrics["wire_bytes"] = jnp.float32(wire_bits / 8.0)
        metrics["density"] = jnp.mean(jnp.stack(dens))
        if telemetry:
            metrics["obs"] = obs_telemetry.Telemetry(
                err_l2=jnp.stack(err_norms),
                density=jnp.stack(dens),
                wire_bytes=jnp.float32(wire_bits / 8.0),
                group_bytes=jnp.asarray(grp_bits, jnp.float32) / 8.0,
                # no robust filtering on the fed server (byz × sampling is a
                # ROADMAP item); the lane slot stays all-zero per its schema
                filtered_lanes=jnp.zeros((c,), jnp.float32),
            )
        new_state = FedState(
            params=params,
            opt_state=new_opt,
            residuals=pool,
            stale=stale,
            key=key,
            round=state.round + 1,
        )
        return new_state, (loss, metrics)

    return round_fn
