"""Non-IID client shards over the synthetic token stream.

Two skew knobs, both deterministic in (spec, seed):

label skew
    every client draws tokens from a contiguous vocab *window*; at
    ``label_skew=0`` the window is the whole vocab (IID), at 1 it narrows to
    the minimum width and windows of distant clients are disjoint — the
    classic label-skew pathology where a sampled cohort's gradients disagree.

scale skew
    per-client dataset sizes follow a power law ``(rank+1)^-size_skew``
    (shuffled so client id doesn't encode rank), rescaled to mean
    ``base_examples`` — these sizes are STATIC host-side numpy, because they
    feed the FedAvg weights and the uniform-weights short-circuit must be
    decidable at trace time (the bitwise pin depends on it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.fed.spec import FedSpec

#: narrowest label window (tokens) a fully-skewed client keeps — the Markov
#: generator needs at least a binary alphabet to have any structure to learn
MIN_WINDOW = 2


def client_sizes(
    n_clients: int, size_skew: float, *, seed: int = 0, base: int = 32
) -> np.ndarray:
    """Static per-client dataset sizes, mean ≈ ``base``, every size >= 1."""
    if size_skew == 0.0:
        return np.full(n_clients, base, dtype=np.int64)
    raw = np.arange(1, n_clients + 1, dtype=np.float64) ** (-size_skew)
    raw *= base * n_clients / raw.sum()
    sizes = np.maximum(1, np.rint(raw)).astype(np.int64)
    return np.random.default_rng(seed).permutation(sizes)


def window_width(vocab: int, label_skew: float) -> int:
    """Static label-window width shared by every client."""
    return max(MIN_WINDOW, int(round(vocab * (1.0 - label_skew))))


def window_lo(cid: jax.Array, n_clients: int, vocab: int, width: int) -> jax.Array:
    """Traced window start for client ``cid``: clients spread evenly over
    ``[0, vocab - width]`` so skewed windows tile the vocab."""
    span = vocab - width
    denom = max(1, n_clients - 1)
    return (cid.astype(jnp.int32) * span) // denom


def make_client_data_fn(spec: FedSpec, *, batch: int, seq: int, vocab: int):
    """Build the round function's data hook: ``data_fn(idx, key, round) ->
    batches`` with a leading cohort axis.

    Each client's tokens come from :func:`repro.data.synthetic.token_batch`
    over its own window (same Markov structure, shifted alphabet) with a key
    folded from (round key, client id) — a client sees the same shard
    regardless of which rounds sample it.
    """
    width = window_width(vocab, spec.label_skew)
    n = spec.n_clients

    def data_fn(idx: jax.Array, key: jax.Array, round_idx: jax.Array):
        kr = jax.random.fold_in(key, round_idx)

        def one(cid):
            kc = jax.random.fold_in(kr, cid)
            b = synthetic.token_batch(kc, batch, seq, width)
            lo = window_lo(cid, n, vocab, width)
            return {"tokens": b["tokens"] + lo, "labels": b["labels"] + lo}

        return jax.vmap(one)(idx)

    return data_fn
