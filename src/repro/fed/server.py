"""Server-side combine and residual-pool bookkeeping.

The server consumes the UNCHANGED bucket wire format: a cohort of C clients
ships exactly what a W-worker data-parallel step ships (one
:class:`~repro.comm.compressed.BucketPayload` per dtype group, leading axis =
sender). What changes is only the combine weighting:

* statically-uniform weights short-circuit to the literal
  :func:`repro.comm.compressed.decode_mean_buckets` — the same ops as the
  ``ef_allgather`` decode, which is what makes participation=1.0 rounds
  bitwise-equal to the data-parallel step (the byz_f=0 idiom);
* sign-family weighted means rescale the per-bucket scales by ``C·w_i``
  before the fused mean kernel (``Σᵢ wᵢ·scaleᵢ·signᵢ ==
  mean_i((C·wᵢ·scaleᵢ)·signᵢ)``) — no extra decode pass;
* generic compressors accumulate ``wᵢ · C⁻¹(payloadᵢ)`` with the same
  two-buffer fori loop as the unweighted decode.

Residual rows of non-sampled clients are carried UNTOUCHED — the scatter
writes only the cohort's rows, which is the paper's guarantee under partial
participation (pinned bitwise in tests/test_fed.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import compressed
from repro.core.compressors import Compressor
from repro.kernels import ops


def weighted_combine(
    comp: Compressor,
    payload_c: compressed.BucketPayload,
    bucket_size: int,
    weights: jax.Array | None,
) -> jax.Array:
    """Combine a cohort payload stack into one (n_buckets, bucket_size) f32.

    ``weights=None`` means statically-uniform: take the unweighted-mean fast
    path (bitwise the data-parallel decode). Otherwise ``weights`` is the
    (C,) normalized FedAvg vector and the result is ``Σᵢ wᵢ·C⁻¹(payloadᵢ)``.
    """
    if weights is None:
        return compressed.decode_mean_buckets(comp, payload_c, bucket_size)
    c = weights.shape[0]
    if compressed.is_sign(comp):
        scaled = payload_c.data["scale"] * (weights * c)[:, None]
        return ops.bucket_decompress_mean(payload_c.data["words"], scaled)

    def body(i, acc):
        pay = jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, False), payload_c.data
        )
        dec = compressed.decode_buckets(comp, compressed.BucketPayload(data=pay), bucket_size)
        return acc + weights[i] * dec

    nb = jax.tree.leaves(payload_c.data)[0].shape[1]
    return jax.lax.fori_loop(0, c, body, jnp.zeros((nb, bucket_size), jnp.float32))


def gather_rows(pool: tuple[jax.Array, ...], idx: jax.Array) -> tuple[jax.Array, ...]:
    """Cohort rows of each group's (n_clients, nb, bs) residual pool."""
    return tuple(p[idx] for p in pool)


def scatter_rows(
    pool: tuple[jax.Array, ...], idx: jax.Array, new: tuple[jax.Array, ...]
) -> tuple[jax.Array, ...]:
    """Write the cohort's fresh residuals back; every other row is carried
    bitwise (``.at[idx].set`` touches exactly the sampled rows — ids are
    distinct by construction, so the scatter is order-independent)."""
    return tuple(p.at[idx].set(n) for p, n in zip(pool, new))
