"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 200 --batch 8 --seq 128 --optimizer ef_signsgd \
        --strategy dense --reduced

On this CPU container use ``--reduced`` (the smoke variant); on a real
cluster drop it and point ``--mesh-data/--mesh-model`` at the slice. The
``--strategy`` flag selects the gradient exchange (dense | ef_allgather |
ef_ring | ef_alltoall | majority_vote | ef_coord_median | ef_trimmed_mean |
ef_norm_filter); ``--overlap`` pipelines the compressed exchange with
backward compute (see README "Async overlap"); ``--byz-attack`` /
``--byz-fraction`` corrupt EF-worker lanes and ``--byz-f`` sets the robust
strategies' declared tolerance (see README "Byzantine robustness").
"""

from __future__ import annotations

import argparse
import json

from repro.comm.api import CommSpec
from repro.comm.backends import BACKEND_CHOICES
from repro.comm.bucketize import DEFAULT_BUCKET_SIZE
from repro.configs import get_config, reduced as make_reduced
from repro.configs.base import BYZ_ATTACKS, ByzConfig, OverlapConfig
from repro.fed.spec import FedSpec
from repro.launch.mesh import make_host_mesh
from repro.obs import sink as obs_sink
from repro.obs.telemetry import TELEMETRY_CHOICES
from repro.train.loop import TrainJob, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--optimizer", default="ef_signsgd")
    ap.add_argument("--strategy", default="dense")
    ap.add_argument("--compressor", default="scaled_sign")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument(
        "--bucket-size", type=int, default=None,
        help="comm-bucket elements (default: repro.comm's 65536; 0 = per-leaf path)",
    )
    ap.add_argument(
        "--backend", default="auto", choices=list(BACKEND_CHOICES),
        help="collective backend for the payload-mean exchange (repro.comm."
        "backends: xla | ring | pallas_dma; auto resolves per mesh — "
        "pallas_dma falls back to ring off-TPU with a logged reason)",
    )
    ap.add_argument(
        "--overlap", action="store_true",
        help="pipeline bucket compression + collectives with backward compute "
        "(repro.overlap; bucketed ef_allgather / ef_ring / majority_vote only "
        "— ef_alltoall's server shards aren't availability-sliceable)",
    )
    ap.add_argument(
        "--overlap-groups", type=int, default=None,
        help="overlap pipeline depth (bucket groups per step; implies --overlap)",
    )
    ap.add_argument(
        "--byz-attack", default=None, choices=list(BYZ_ATTACKS),
        help="fault injection: corrupt EF-worker lanes with this attack "
        "(repro.comm.adversary; any --byz-* flag enables the byz path)",
    )
    ap.add_argument(
        "--byz-fraction", type=float, default=None,
        help="fraction of EF workers the injector corrupts (floor(frac*W) lanes)",
    )
    ap.add_argument(
        "--byz-f", type=int, default=None,
        help="declared adversary tolerance for the robust strategies "
        "(ef_coord_median / ef_trimmed_mean / ef_norm_filter; needs 2f < W)",
    )
    ap.add_argument(
        "--byz-scale", type=float, default=None,
        help="attack magnitude for scaled_noise / const_drift (default 10.0)",
    )
    ap.add_argument(
        "--clients", type=int, default=None,
        help="federated tier (repro.fed): simulate this many clients; any "
        "--clients/--cohort/--participation/--shard-skew flag enables fed "
        "rounds (needs --strategy ef_allgather; steps count rounds, --batch "
        "is per-client)",
    )
    ap.add_argument(
        "--cohort", type=int, default=None,
        help="clients sampled per federated round (absolute; exclusive with "
        "--participation; a cohort of 0 is rejected at spec validation)",
    )
    ap.add_argument(
        "--participation", type=float, default=None,
        help="fraction of clients sampled per round, in (0, 1]; a fraction "
        "that rounds to 0 clients is rejected at spec validation",
    )
    ap.add_argument(
        "--shard-skew", type=float, default=None,
        help="non-IID label skew in [0, 1]: narrows each client's vocab "
        "window (0 = IID, 1 = disjoint minimal windows)",
    )
    ap.add_argument(
        "--size-skew", type=float, default=None,
        help="power-law exponent of per-client dataset sizes (feeds the "
        "FedAvg weights; 0 = uniform sizes)",
    )
    ap.add_argument(
        "--fed-staleness", type=int, default=None,
        help="async-round mode: mix the applied update from the last D+1 "
        "round aggregates with 1/(1+age) staleness weights (0 = synchronous)",
    )
    ap.add_argument(
        "--telemetry", default="off", choices=list(TELEMETRY_CHOICES),
        help="in-graph telemetry level (repro.obs): 'full' records per-group "
        "EF-residual norms, densities and exact wire bytes each logged step; "
        "'off' compiles to the exact untelemetered program",
    )
    ap.add_argument(
        "--log-dir", default="",
        help="write a schema-versioned run.jsonl of run records here "
        "(summarize with `python -m repro.obs report <file>`)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    bucket_size = DEFAULT_BUCKET_SIZE
    if args.bucket_size is not None:
        bucket_size = args.bucket_size or None  # 0 → per-leaf fallback
    spec = CommSpec(
        strategy=args.strategy,
        compressor=args.compressor,
        bucket_size=bucket_size,
        backend=args.backend,
        overlap=OverlapConfig.from_args(args.overlap, args.overlap_groups),
        byz=ByzConfig.from_args(args.byz_attack, args.byz_fraction, args.byz_f, args.byz_scale),
        telemetry=args.telemetry,
        fed=FedSpec.from_args(
            args.clients, args.cohort, args.participation,
            args.shard_skew, args.size_skew, args.fed_staleness,
        ),
    ).validate()  # reject bad flag combinations before any compile
    job = TrainJob(
        cfg=cfg, mesh=mesh, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, momentum=args.momentum, weight_decay=args.weight_decay,
        optimizer=args.optimizer, compressor=args.compressor,
        policy=args.policy, seed=args.seed,
        microbatches=args.microbatches, comm=spec,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_dir=args.log_dir,
    )
    _, history = run_training(job, log_fn=lambda r: print(json.dumps(r), flush=True))
    # epilogue from the unconditional final record — history[-1] raised
    # IndexError on zero-step runs
    final = obs_sink.final_record(history, steps=args.steps)
    print(json.dumps(final), flush=True)
    fl = final["final_loss"]
    print(f"final_loss={fl:.4f}" if fl is not None else "final_loss=nan")


if __name__ == "__main__":
    main()
