"""ShapeDtypeStruct stand-ins for every (architecture × input shape) pair.

No device allocation — these feed ``jax.jit(...).lower()`` for the multi-pod
dry-run. Modality frontends are stubbed per the brief: VLM archs get
precomputed patch embeddings, the audio arch gets precomputed encoder frame
embeddings (the transformer backbone is what we build).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import InputShape, ModelConfig

S = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    toks = s
    batch: dict = {}
    if cfg.num_patch_tokens:
        toks = s - cfg.num_patch_tokens
        batch["patch_embeds"] = S((b, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["frames"] = S((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    batch["tokens"] = S((b, toks), jnp.int32)
    batch["labels"] = S((b, toks), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_inputs_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """One new token against a seq_len-deep cache."""
    b = shape.global_batch
    return {"tokens": S((b, 1), jnp.int32), "pos": S((), jnp.int32)}


def cache_struct(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree matching transformer.init_cache (no allocation)."""
    from repro.models import transformer

    zeros = jax.eval_shape(
        lambda: transformer.init_cache(
            cfg, shape.global_batch, shape.seq_len, dtype, with_memory=bool(cfg.encoder_layers)
        )
    )
    return zeros


def long_context_variant(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k policy (DESIGN.md §4): SSM/hybrid run natively; attention
    archs decode via the sliding-window ring cache (window 8192)."""
    if shape.name != "long_500k":
        return cfg
    if cfg.arch_type in ("ssm", "hybrid"):
        return cfg
    window = cfg.sliding_window or 8192
    return dataclasses.replace(cfg, sliding_window=min(window, 8192))


def tokens_in_step(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.kind == "decode":
        return shape.global_batch  # one token per sequence
    return shape.global_batch * shape.seq_len
