"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single CPU device.

Topology (TPU v5e target):
  single-pod: 16×16 = 256 chips, axes (data, model)
  multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
  is pure data-parallel and is where EF-compressed gradient aggregation runs
  (DESIGN.md §5).
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.5: meshes carry explicit per-axis sharding modes
    from jax.sharding import AxisType
except ImportError:  # jax ≤ 0.4.x: every axis is implicitly Auto
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def use_mesh(mesh):
    """Version-compat mesh context: ``jax.set_mesh`` where it exists (jax ≥
    0.6), else the 0.4.x ``Mesh`` resource-env context manager — both make
    ``mesh`` the ambient mesh for jit/shard_map inside the ``with`` block."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (fake) devices the host exposes."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))


def dp_axis_names(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def ef_axis_names(mesh, policy: str) -> tuple[str, ...]:
    """Mesh axes treated as EF 'workers' (manual in shard_map).

    Multi-pod: the pod axis — compression rides the expensive inter-pod hop
    and params may still be fsdp-sharded intra-pod. Single-pod: the data axis,
    valid only when params are not data-sharded (dp/tp policies); fsdp runs
    single-worker EF (the paper's Alg. 2 per shard) instead.
    """
    if "pod" in mesh.axis_names:
        return ("pod",)
    if policy in ("dp", "tp") and "data" in mesh.axis_names:
        return ("data",)
    return ()
