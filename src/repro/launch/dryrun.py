import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production meshes and record memory/cost/collective analysis.

The two lines above MUST stay first — jax locks the device count on first
init, and the dry-run needs 512 placeholder CPU devices to build the
2×16×16 production mesh. (Smoke tests / benches import other entrypoints and
see the single real device.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 × both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.comm.api import CommSpec
from repro.configs import ARCH_IDS, get_config
from repro.core import optim
from repro.core.compressors import ScaledSignCompressor
from repro.launch import specs as SP
from repro.launch.mesh import ef_axis_names, make_production_mesh, use_mesh
from repro.models.config import INPUT_SHAPES
from repro.sharding.rules import ShardingRules, default_policy
from repro.train import steps as steps_lib
from repro.train.state import abstract_train_state
from repro.utils import hlo as hlo_util

# TPU v5e constants (per chip / per link) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun")


def _cap_cell(cell: str, width: int = 40) -> str:
    """One capability-matrix cell for terminal display: rejection reasons can
    quote a full CommSpecError — keep the first clause, mark the cut."""
    return cell if len(cell) <= width else cell[: width - 3] + "..."


def _mem_dict(mem) -> dict:
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if callable(v):
            v = v()
        if v is not None:
            out[k] = int(v)
    return out


def lower_combo(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    strategy: str = "auto",
    policy: str | None = None,
    keep_hlo: bool = False,
    attn_chunk: int | None = None,
    remat: bool | None = None,
):
    """Lower+compile one (arch × shape × mesh); return the roofline record."""
    import dataclasses

    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    cfg = SP.long_context_variant(cfg, shape)
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    policy = policy or default_policy(cfg)
    rules = ShardingRules(cfg, mesh, policy)

    if strategy == "auto":
        # paper-faithful default for training: EF-sign aggregation over the
        # manual worker axes (data single-pod, pod multi-pod); fsdp policies
        # on a single pod run single-worker Alg.2 via the dense path.
        ef_axes = ef_axis_names(mesh, policy)
        strategy = "ef_allgather" if ef_axes else "dense"
    else:
        ef_axes = ef_axis_names(mesh, policy) if strategy != "dense" else ()

    t0 = time.time()
    key = jax.random.PRNGKey(0)

    obs_meta = None
    if shape.kind == "train":
        # EF residuals in bf16 for bf16-param configs (DESIGN.md §8.3)
        err_dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
        chain = (
            optim.ef_sgd(1e-2, error_dtype=err_dt) if strategy == "dense" else optim.sgd(1e-2)
        )
        state_abs = abstract_train_state(
            cfg, key, chain, strategy, mesh, ef_axes, error_dtype=err_dt
        )
        batch_abs = SP.train_batch_specs(cfg, shape)
        # per-leaf fallback path (bucket_size=None): preserves intra-leaf
        # shardings, which is what the giant-model dry-run inspects
        spec = CommSpec(
            strategy=strategy, compressor=ScaledSignCompressor(), bucket_size=None
        )
        bundle = steps_lib.make_train_step(
            cfg, mesh, rules,
            spec=spec, local_chain=chain,
            ef_axes=ef_axes, batch_example=batch_abs, state_example=state_abs,
        )
        args = (state_abs, batch_abs)
        # what a real (bucketed) run of this combo will record: the telemetry
        # field table and each strategy's exact per-device wire bill at the
        # default bucket size — the dry run documents the run-record contract
        from repro.comm import backends as comm_backends
        from repro.comm import bucketize as comm_bucketize
        from repro.comm import collective as comm_collective
        from repro.obs import telemetry as obs_telemetry

        layout = comm_bucketize.build_layout(state_abs.params, comm_bucketize.DEFAULT_BUCKET_SIZE)
        world = comm_collective.world_size(mesh, ef_axes) if ef_axes else 1
        obs_meta = {
            "telemetry_fields": list(obs_telemetry.telemetry_schema()),
            "ef_world": world,
            "bucket_size": comm_bucketize.DEFAULT_BUCKET_SIZE,
            "wire_models": obs_telemetry.strategy_wire_models(layout, world),
            # strategy × backend capability table on THIS mesh: which
            # transports each strategy rides (robust included — slot-native
            # exchange), which cells degrade, and why a cell is rejected
            "backend_capabilities": (
                comm_backends.capability_matrix(mesh, ef_axes) if ef_axes else None
            ),
        }
    elif shape.kind == "prefill":
        from repro.models import transformer

        params_abs = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)
        batch_abs = SP.prefill_batch_specs(cfg, shape)
        cache_abs = SP.cache_struct(cfg, shape)
        bundle = steps_lib.make_prefill_step(
            cfg, mesh, rules, batch_example=batch_abs, cache_example=cache_abs,
            params_example=params_abs,
        )
        args = (params_abs, batch_abs, cache_abs)
    else:  # decode
        from repro.models import transformer

        params_abs = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)
        cache_abs = SP.cache_struct(cfg, shape)
        dec_in = SP.decode_inputs_specs(cfg, shape)
        bundle = steps_lib.make_decode_step(
            cfg, mesh, rules, cache_example=cache_abs, params_example=params_abs,
        )
        args = (params_abs, cache_abs, dec_in["tokens"], dec_in["pos"])

    with use_mesh(mesh):
        jitted = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings, out_shardings=bundle.out_shardings
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    lower_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = _mem_dict(compiled.memory_analysis())
    hlo_text = compiled.as_text()
    # trip-count-aware accounting: XLA cost_analysis counts while bodies once,
    # underreporting scan-over-layers programs by the trip count (repro.utils.hlo)
    parsed = hlo_util.analyze(hlo_text)
    coll = parsed["collective_bytes"]

    flops_dev = float(parsed["dot_flops"])
    bytes_dev = float(parsed["hbm_bytes"])
    coll_dev = float(coll["total_bytes"])
    tokens = SP.tokens_in_step(cfg, shape)
    model_flops = cfg.model_flops(tokens, forward_only=shape.kind != "train")

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "policy": policy,
        "strategy": strategy,
        "kind": shape.kind,
        "lower_compile_s": round(lower_s, 1),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collectives": coll["by_kind_bytes"],
            "collective_counts": coll["by_kind_count"],
            # XLA's own (loop-bodies-once) numbers, for reference:
            "xla_cost_flops": float(cost.get("flops", 0.0)),
            "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": mem,
        "roofline": {
            # per the brief: global HLO quantities over aggregate capacity ==
            # per-device quantities over per-chip capacity (SPMD program)
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(flops_dev * chips, 1.0),
    }
    dom = max(rec["roofline"], key=lambda k: rec["roofline"][k])
    rec["roofline"]["dominant"] = dom
    if obs_meta is not None:
        rec["obs"] = obs_meta
    if keep_hlo:
        rec["hlo_ops"] = hlo_util.op_histogram(hlo_text)
        rec["_hlo_text"] = hlo_text
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--dump-hlo", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                name = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
                if args.tag:
                    name += f"__{args.tag}"
                path = os.path.join(args.out, name + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {name}")
                    continue
                print(f"[lower] {name} ...", flush=True)
                try:
                    rec = lower_combo(
                        arch, shape, multi_pod=multi_pod,
                        strategy=args.strategy, policy=args.policy,
                        keep_hlo=args.dump_hlo,
                    )
                    hlo_text = rec.pop("_hlo_text", None)
                    if hlo_text is not None:
                        with gzip.open(path[:-5] + ".hlo.gz", "wt") as f:
                            f.write(hlo_text)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"  ok {rec['lower_compile_s']}s dominant={r['dominant']} "
                        f"compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
                        f"collective={r['collective_s']:.3f}s "
                        f"temp={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                        flush=True,
                    )
                    if "obs" in rec:
                        ob = rec["obs"]
                        fields = ",".join(f["name"] for f in ob["telemetry_fields"])
                        models = " ".join(
                            f"{s}={b / 2**20:.1f}MiB"
                            for s, b in sorted(ob["wire_models"].items())
                        )
                        print(f"  obs: telemetry fields [{fields}]", flush=True)
                        print(
                            f"  obs: wire/step/device @W={ob['ef_world']} "
                            f"bs={ob['bucket_size']}: {models}",
                            flush=True,
                        )
                        caps = ob.get("backend_capabilities")
                        if caps:
                            cols = sorted(next(iter(caps.values())))
                            print(
                                "  obs: backend capability matrix "
                                f"(strategy x {'/'.join(cols)}):",
                                flush=True,
                            )
                            for strategy, row in caps.items():
                                cells = "  ".join(f"{b}={_cap_cell(row[b])}" for b in cols)
                                print(f"    {strategy:16s} {cells}", flush=True)
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    err = {"arch": arch, "shape": shape, "mesh": multi_pod,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    with open(path + ".err", "w") as f:
                        json.dump(err, f, indent=1)
                    print(f"  FAIL {type(e).__name__}: {str(e)[:300]}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
