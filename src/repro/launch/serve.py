"""Serving launcher: prefill a batch of synthetic prompts and decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 16

On a real slice drop ``--reduced`` and set the mesh flags; the engine places
params per the arch's sharding policy and jits prefill/decode with the same
bundles the dry-run lowers.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced as make_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serve.engine import DecodeEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    engine = DecodeEngine(
        cfg, mesh, params,
        ServeConfig(max_len=args.max_len, temperature=args.temperature),
        policy=args.policy,
    )
    prompt = {
        "tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    }
    if cfg.num_patch_tokens:
        prompt["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_patch_tokens, cfg.d_model)
        )
    if cfg.encoder_layers:
        prompt["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)
        )
    t0 = time.time()
    out = engine.generate(prompt, new_tokens=args.new_tokens, seed=args.seed)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s ({toks / dt:.1f} tok/s on this host)")
    for row in out[: min(4, args.batch)]:
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
