"""Batched decode engine: prefill a batch of prompts, then step the decoder.

Greedy or temperature sampling; uniform-position batches (the dry-run's
decode shapes are exactly one engine step against a deep cache).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch.mesh import use_mesh
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.sharding.rules import ShardingRules
from repro.train import steps as steps_lib


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    cache_dtype: Any = jnp.bfloat16


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, mesh, params, serve_cfg: ServeConfig | None = None, policy: str | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.serve_cfg = serve_cfg or ServeConfig()
        self.rules = ShardingRules(cfg, mesh, policy)
        self.params = jax.device_put(params, self.rules.named(self.rules.param_specs(params)))
        self._decode = None
        self._prefill = None

    # -------------------------------------------------------------- #

    def _fresh_cache(self, batch_size: int):
        return transformer.init_cache(
            self.cfg, batch_size, self.serve_cfg.max_len, self.serve_cfg.cache_dtype,
            with_memory=bool(self.cfg.encoder_layers),
        )

    def _build(self, batch_size: int, prompt: dict):
        cache = self._fresh_cache(batch_size)
        pre = steps_lib.make_prefill_step(
            self.cfg, self.mesh, self.rules,
            batch_example=prompt, cache_example=cache, params_example=self.params,
        )
        dec = steps_lib.make_decode_step(
            self.cfg, self.mesh, self.rules,
            cache_example=cache, params_example=self.params,
        )
        self._prefill = pre.jit()
        self._decode = dec.jit()
        return cache

    def _sample(self, logits, key):
        logits = logits[:, -1, : self.cfg.vocab_size].astype(jnp.float32)
        if self.serve_cfg.temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.serve_cfg.temperature, axis=-1)

    # -------------------------------------------------------------- #

    def generate(self, prompt: dict, new_tokens: int, seed: int = 0):
        """prompt: {tokens (B,S), [patch_embeds], [frames]} → (B, new_tokens)."""
        out, _ = self._generate(prompt, new_tokens, seed, timed=False)
        return out

    def generate_timed(self, prompt: dict, new_tokens: int, seed: int = 0):
        """Like :meth:`generate` but fences every step and returns latency
        stats: ``(tokens, {"prefill_us", "decode_us_per_token", "decode_us_median",
        "tokens_per_s"})``. Used by the serve bench suite; the untimed path
        stays free of host syncs."""
        return self._generate(prompt, new_tokens, seed, timed=True)

    def _generate(self, prompt: dict, new_tokens: int, seed: int, *, timed: bool):
        import time as _time

        tokens = prompt["tokens"]
        b, s = tokens.shape
        cache = self._build(b, prompt)
        if self.cfg.encoder_layers and "frames" in prompt:
            cache["memory"] = transformer.encode(self.params, self.cfg, prompt["frames"])
        stats = None
        with use_mesh(self.mesh):
            if timed:
                # warm the compile on a throwaway cache (prefill donates its
                # cache argument) so prefill_us measures runtime, not jit
                warm = self._fresh_cache(b)
                if self.cfg.encoder_layers and "frames" in prompt:
                    # copy: donation of warm must not invalidate the real cache
                    warm["memory"] = jnp.copy(cache["memory"])
                jax.block_until_ready(self._prefill(self.params, prompt, warm))
            t0 = _time.perf_counter() if timed else 0.0
            logits, cache = self._prefill(self.params, prompt, cache)
            if timed:
                jax.block_until_ready(logits)
                prefill_us = (_time.perf_counter() - t0) * 1e6
            key = jax.random.PRNGKey(seed)
            pos = s + (self.cfg.num_patch_tokens if self.cfg.num_patch_tokens and "patch_embeds" in prompt else 0)
            out = []
            step_us = []
            tok = self._sample(logits, key)
            for i in range(new_tokens):
                out.append(tok)
                key, sub = jax.random.split(key)
                t0 = _time.perf_counter() if timed else 0.0
                logits, cache = self._decode(
                    self.params, cache, tok[:, None], jnp.int32(pos + i)
                )
                tok = self._sample(logits, sub)
                if timed:
                    jax.block_until_ready(tok)
                    step_us.append((_time.perf_counter() - t0) * 1e6)
            if timed:
                # first decode step pays compile; steady-state excludes it
                steady = step_us[1:] or step_us
                median = sorted(steady)[len(steady) // 2] if steady else 0.0
                stats = {
                    "prefill_us": prefill_us,
                    "decode_us_per_token": sum(steady) / len(steady) if steady else 0.0,
                    "decode_us_median": median,
                    "tokens_per_s": b * 1e6 / median if median else 0.0,
                }
            return jnp.stack(out, axis=1) if out else jnp.zeros((b, 0), jnp.int32), stats
