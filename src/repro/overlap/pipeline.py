"""The overlap executor: per-group compress → collective pipelining.

``build_overlapped_aggregator`` (reached via ``repro.comm.make_aggregator``
with ``spec.overlap`` set) is a drop-in for the one-shot bucketed
aggregator that executes the
exchange per :class:`~repro.overlap.schedule.OverlapSchedule` group instead
of in one shot. Inside the (fully-manual) ``shard_map`` body the groups are
laid out in reverse-AD availability order as independent dataflow chains:

    encode(g0) → collective(g0) ─┐
    encode(g1) → collective(g1) ─┤→ decode + scatter
    encode(g2) → collective(g2) ─┘

Nothing in group k+1's encode depends on group k's collective, so the XLA
latency-hiding scheduler is free to run collective *k* while *k+1* is still
compressing (and, with the staged grad-fn of :mod:`repro.train.steps`
feeding the step, while earlier layers' backward still runs). On CPU the
fake-device collectives execute inline — the pipeline's wall-clock win there
is ~nil by construction, which is why the bench suite additionally evaluates
the measured per-group component times through :func:`exposure_report`
(the standard pipeline latency model) to report how much communication the
schedule leaves exposed.

Numerics are IDENTICAL to the one-shot path: buckets are compressed by the
same per-bucket kernels on row slices, stochastic compressors draw the same
per-bucket keys (the full ``split`` is computed once and sliced), and wire /
density accounting reduces in the same order — the 5-step trajectory test in
tests/test_overlap.py pins bitwise equality.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm import bucketize, compressed, exchange, robust
from repro.comm.collective import _default_backend, _worker_index, world_size
from repro.core.aggregation import AggInfo
from repro.core.compressors import Compressor, ScaledSignCompressor
from repro.obs import telemetry as obs_telemetry
from repro.overlap.schedule import OverlapSchedule
from repro.utils import compat

AxisNames = tuple[str, ...]

# strategies the pipeline can slice per group. ef_alltoall's server-sharded
# bucket streams are partitioned across workers, not availability ranks, so
# it stays on the one-shot path; dense has no compression stage to pipeline
# (train/steps.py routes it to its own GSPMD path before this is reached).
# The robust strategies pipeline their slot exchanges per group and defer the
# order-statistics combine to phase 2, where the per-dtype-group stacks are
# reassembled — same estimator input as the one-shot path, so the combine is
# bitwise-identical (slot-native exchange, PR 10).
OVERLAP_STRATEGIES = ("ef_allgather", "ef_ring", "majority_vote") + robust.ROBUST_STRATEGIES


def make_overlapped_aggregator(
    strategy: str,
    comp: Compressor | None,
    layout: bucketize.BucketLayout,
    schedule: OverlapSchedule,
    mesh,
    ef_axes: AxisNames,
):
    """Deprecated legacy factory — build a :class:`repro.comm.api.CommSpec`
    with ``overlap=OverlapConfig(...)`` and call
    :func:`repro.comm.api.make_aggregator` instead (it derives the schedule
    from the parameter tree). This shim keeps working for callers that built
    their own :class:`OverlapSchedule`."""
    warnings.warn(
        "make_overlapped_aggregator() is deprecated; build a CommSpec with "
        "overlap=OverlapConfig(...) and call repro.comm.make_aggregator(spec, "
        "layout, mesh, ef_axes, params=...)",
        DeprecationWarning,
        stacklevel=2,
    )
    return build_overlapped_aggregator(strategy, comp, layout, schedule, mesh, ef_axes)


def build_overlapped_aggregator(
    strategy: str,
    comp: Compressor | None,
    layout: bucketize.BucketLayout,
    schedule: OverlapSchedule,
    mesh,
    ef_axes: AxisNames,
    *,
    backend=None,
    telemetry: bool = False,
    byz_f: int = 0,
):
    """Schedule-driven aggregator with the same signature/contract as the
    one-shot ``build_bucketed_aggregator``: ``fn(buckets_w, err_w, srv_w,
    key) -> (agg, new_err_w, new_srv_w, info)``.

    ``backend`` carries the payload-exchange transport (see
    :mod:`repro.comm.backends`); each group's exchange is one slot-native
    :class:`~repro.comm.exchange.PayloadStack` view. Fused-mean backends
    (ring / DMA) collapse transport+decode into phase-1 per-hop units;
    gather-style backends issue the collective in phase 1 and defer the mean
    reading to phase 2 — both orders are bitwise-identical to the one-shot
    path. The robust strategies (``byz_f > 0``) stage the views, reassemble
    each dtype group's (W, nb, bs) slot stack in phase 2, and run the
    order-statistics combine on the full group — the identical estimator
    input (and result) as the one-shot robust path. ``telemetry`` adds the
    :class:`repro.obs.telemetry.Telemetry` aux output on ``info.telemetry``;
    here ``group_bytes`` splits the wire bill per *schedule* group (the unit
    the pipeline exposes or hides), feeding the comm-exposure model directly.
    """
    if strategy not in OVERLAP_STRATEGIES:
        raise ValueError(
            f"overlap supports {OVERLAP_STRATEGIES}, got {strategy!r} "
            "(ef_alltoall's server shards aren't availability-sliceable)"
        )
    if schedule.layout is not layout and schedule.layout != layout:
        raise ValueError("schedule was built for a different BucketLayout")
    comp = comp or ScaledSignCompressor()
    if backend is None:
        backend = _default_backend(strategy)
    w = world_size(mesh, ef_axes)
    bs = layout.bucket_size
    ef = ef_axes if len(ef_axes) != 1 else ef_axes[0]
    masks = tuple(bucketize.valid_mask(layout, gi) for gi in range(len(layout.groups)))
    bucket_bits = comp.wire_bits(bs)
    has_err = strategy != "majority_vote"
    # byz_f == 0 robust collapses to the mean reading (bitwise ef_allgather)
    robust_mode = strategy in robust.ROBUST_STRATEGIES and byz_f > 0
    n_dtype = len(layout.groups)

    def body(buckets, err, srv, key):
        del srv
        widx = _worker_index(ef_axes)
        keys_full = [None] * n_dtype
        if not comp.deterministic:
            for gi in range(n_dtype):
                gkey = jax.random.fold_in(jax.random.fold_in(key, widx), gi)
                keys_full[gi] = jax.random.split(gkey, buckets[gi][0].shape[0])

        # ---- phase 1: per group, encode slices then issue the collective.
        # Each iteration is an independent dataflow chain — collective k and
        # encode k+1 have no data dependency, which is the pipeline.
        staged = []  # [(slice, encoded/new_err/dens, collective result)]
        wire_bits = 0.0
        grp_bits: list[float] = []  # telemetry: wire split per SCHEDULE group
        for grp in schedule.groups:
            g_bits = 0.0
            for sl in grp.slices:
                b = buckets[sl.group][0][sl.start : sl.stop]
                m = masks[sl.group][sl.start : sl.stop]
                nb = sl.n_buckets
                if strategy == "majority_vote":
                    s = jnp.where(b >= 0, 1.0, -1.0)
                    tot = lax.psum(s, ef_axes)
                    staged.append((sl, None, None, jnp.where(tot >= 0, 1.0, -1.0) * m))
                    wire_bits += (w - 1) * nb * bs
                    g_bits += (w - 1) * nb * bs
                else:
                    e = err[sl.group][0][sl.start : sl.stop]
                    ks = keys_full[sl.group]
                    payload, ne, d_b = compressed.ef_encode_buckets(
                        comp, b, e, mask=m, keys=None if ks is None else ks[sl.start : sl.stop]
                    )
                    view = backend.exchange(comp, payload, bs, ef_axes, w)
                    if robust_mode or not backend.fused_mean:
                        # gather-style transports issue their collective at
                        # exchange time; the decode reading defers to phase 2
                        # (the robust combine always defers — it needs the
                        # reassembled per-group stack)
                        staged.append((sl, ne, d_b, view))
                    else:
                        # fused transports: the whole per-hop exchange is the
                        # schedulable phase-1 unit
                        staged.append((sl, ne, d_b, view.mean()))
                    wire_bits += (w - 1) * nb * bucket_bits
                    g_bits += (w - 1) * nb * bucket_bits
            grp_bits.append(g_bits)

        # ---- phase 2: read the staged exchange views, scatter into full
        # stacks. Robust mode reassembles each dtype group's (W, nb, bs)
        # slot stack from the slice decodes and combines once per group —
        # the one-shot estimator input, so the combine is value-identical.
        outs = [jnp.zeros((g.n_buckets, bs), jnp.float32) for g in layout.groups]
        new_errs = [jnp.zeros((g.n_buckets, bs), jnp.float32) for g in layout.groups]
        dens_full = [jnp.ones((g.n_buckets,), jnp.float32) for g in layout.groups]
        stacks = (
            [jnp.zeros((w, g.n_buckets, bs), jnp.float32) for g in layout.groups]
            if robust_mode
            else []
        )
        lane_w = jnp.zeros((w,), jnp.float32)
        for sl, ne, d_b, result in staged:
            if isinstance(result, exchange.PayloadStack):
                if robust_mode:
                    stacks[sl.group] = (
                        stacks[sl.group].at[:, sl.start : sl.stop].set(result.decoded())
                    )
                    result = None
                else:
                    result = result.mean()
            if result is not None:
                outs[sl.group] = outs[sl.group].at[sl.start : sl.stop].set(result)
            if ne is not None:
                new_errs[sl.group] = new_errs[sl.group].at[sl.start : sl.stop].set(ne)
                dens_full[sl.group] = dens_full[sl.group].at[sl.start : sl.stop].set(d_b)
        if robust_mode:
            for gi in range(n_dtype):
                outs[gi] = robust.combine_stack(strategy, stacks[gi], byz_f)
                if telemetry:
                    lane_w = lane_w + robust.filtered_lane_weights(strategy, stacks[gi], byz_f)

        # identical reduction order to the one-shot body: per dtype group
        # mean, then mean over groups, then pmean
        dens = [jnp.mean(d) if has_err else jnp.float32(1.0) for d in dens_full]
        tele = None
        if telemetry:
            err_norms = [
                obs_telemetry.residual_l2(ne) if has_err else jnp.float32(0.0)
                for ne in new_errs
            ]
            tele = obs_telemetry.Telemetry(
                err_l2=lax.pmean(jnp.stack(err_norms), ef_axes),
                density=lax.pmean(jnp.stack(dens), ef_axes),
                wire_bytes=jnp.float32(wire_bits / 8.0),
                group_bytes=jnp.asarray(grp_bits, jnp.float32) / 8.0,
                filtered_lanes=lane_w,
            )
        info = AggInfo(
            wire_bytes_per_device=jnp.float32(wire_bits / 8.0),
            mean_density=lax.pmean(jnp.mean(jnp.stack(dens)), ef_axes),
            telemetry=tele,
        )
        return (
            tuple(outs),
            tuple(e[None] for e in new_errs) if has_err else (),
            (),
            info,
        )

    stacked = tuple(P(ef) for _ in range(n_dtype))
    in_specs = (stacked, stacked if has_err else (), (), P())
    out_specs = (
        tuple(P() for _ in range(n_dtype)),
        stacked if has_err else (),
        (),
        AggInfo(
            wire_bytes_per_device=P(),
            mean_density=P(),
            telemetry=obs_telemetry.replicated_specs() if telemetry else None,
        ),
    )
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, manual_axes=None
    )


# ---------------------------------------------------------------------------
# pipeline latency model (exposure accounting)
# ---------------------------------------------------------------------------


def exposure_report(
    avail_us: tuple[float, ...] | list[float],
    comm_us: tuple[float, ...] | list[float],
    *,
    tail_us: float = 0.0,
) -> dict:
    """Evaluate the pipeline schedule on measured per-group component times.

    ``avail_us[k]`` — wall time (from step start) at which group *k*'s
    compressed payload is ready to ship (backward + compress progress);
    must be non-decreasing in the schedule's issue order. ``comm_us[k]`` —
    the group's collective time on a serial wire. ``tail_us`` — compute that
    still runs after the last payload is ready (decode/apply of early
    groups can hide trailing comm too).

    Standard single-wire pipeline recurrence: collective *k* starts when its
    payload is ready AND the wire is free::

        finish_k = max(finish_{k-1}, avail_k) + comm_k

    ``exposed_us`` is how much of the comm bill the step actually waits on —
    ``finish_{n-1} − (avail_{n-1} + tail_us)``, clamped at 0 — vs
    ``serial_comm_us = Σ comm_k``, the bill the one-shot path pays in full.
    One group degenerates to exposure = its full comm time.
    """
    if len(avail_us) != len(comm_us) or not comm_us:
        raise ValueError("need one availability time per comm time (>= 1 group)")
    if any(b < a for a, b in zip(avail_us, avail_us[1:])):
        raise ValueError(f"avail_us must be non-decreasing, got {avail_us!r}")
    finish = 0.0
    for a, c in zip(avail_us, comm_us):
        finish = max(finish, a) + c
    compute_end = avail_us[-1] + tail_us
    serial = float(sum(comm_us))
    exposed = max(0.0, finish - compute_end)
    return {
        "serial_comm_us": serial,
        "exposed_us": exposed,
        "exposure_frac": exposed / serial if serial else 0.0,
        "finish_us": finish,
        "compute_us": compute_end,
        "hidden_us": serial - exposed,
    }


def proportional_exposure(
    group_bytes: list[float] | tuple[float, ...],
    compute_us: float,
    serial_comm_us: float,
    *,
    tail_us: float = 0.0,
) -> dict:
    """:func:`exposure_report` under the proportional-split assumption.

    When only aggregate times are known — a backward+compress span of
    ``compute_us`` and a serial exchange bill of ``serial_comm_us`` — the
    standard simplification spreads both over the schedule by wire bytes:
    group *k*'s payload is ready at ``compute_us · cum_bytes_k/total`` and
    its hop costs ``serial_comm_us · bytes_k/total``. Both the overlap bench
    suite (measured step/exchange walls) and the ``--overlap`` example
    (analytic wire @ reference bandwidth) feed this one helper so the model
    they report is the same by construction.
    """
    total = float(sum(group_bytes))
    if total <= 0:
        raise ValueError(f"group_bytes must sum positive, got {group_bytes!r}")
    avail, comm, cum = [], [], 0.0
    for b in group_bytes:
        cum += b
        avail.append(compute_us * cum / total)
        comm.append(serial_comm_us * b / total)
    return exposure_report(avail, comm, tail_us=tail_us)
