"""Compatibility shim: the ring exchange moved to the backend registry.

The double-buffered ppermute ring was promoted verbatim to
:mod:`repro.comm.backends.ring` when the collective transports became
pluggable (``CommSpec.backend``) — the overlap pipeline now receives it as a
resolved :class:`~repro.comm.backends.CollectiveBackend` instead of importing
this module. Kept as a silent re-export so existing imports keep working;
new code should import from ``repro.comm.backends``.
"""

from repro.comm.backends.ring import RingBackend, ring_axis, ring_decode_mean

__all__ = ["RingBackend", "ring_axis", "ring_decode_mean"]
