"""Static overlap schedule: group buckets by reverse-AD availability.

The schedule is computed ONCE per (BucketLayout, param structure) — like the
layout itself it needs shapes only, no device data — and is a pure function
of its inputs, so identical inputs always produce identical groups (the
scheduler-determinism contract tests/test_overlap.py pins).

Two ingredients:

* **Availability ranks.** Each param leaf gets an integer rank ordering when
  its gradient becomes available during reverse-mode AD: the LM head and
  final norm backward first (rank 0), the block stack next (the ``lax.scan``
  over layers makes the whole stack one atomic rank — per-layer grads are
  not splittable through a scan, which is exactly the fallback case the
  pipeline executor handles), the encoder after it, and the embedding table
  last (its backward is the final op of the pass, and under weight tying it
  also accumulates the head's contribution). Trees that don't look like our
  transformer fall back to reversed flatten order — leaves used later in the
  forward produce gradients earlier in the backward.

* **Greedy byte balancing.** Buckets are ordered by (rank, group, index) and
  the ordered stream is cut into ``n_groups`` contiguous segments of
  near-equal wire bytes. Contiguity in availability order is what makes the
  pipeline legal (group k is fully available before group k+1's issue
  point); byte balance is what keeps every pipeline stage's collective the
  same length. A bucket that straddles a stage boundary takes the max rank
  of its leaves — it is only ready when its *last* gradient is.

Ranks order bucket *issue*, nothing else: the EF residual layout, the wire
format and the aggregated result are all schedule-independent, so
``--overlap-groups`` can change between runs (or mid-training via restart)
without touching checkpoints.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.comm.bucketize import BucketLayout
from repro.core.compressors import Compressor, ScaledSignCompressor

# decoder params whose grads arrive first/last in reverse-AD order; keys are
# matched against the flattened tree path of each leaf
_STAGE_RANKS = (
    ("encoder", 2),  # runs before the decoder stack → backward after it
    ("final_norm", 0),
    ("head", 0),
    ("embed", 3),  # embedding backward is the last op of the pass
    ("blocks", 1),
)


@dataclasses.dataclass(frozen=True)
class GroupSlice:
    """A contiguous run of buckets inside one dtype group's stream."""

    group: int  # index into BucketLayout.groups
    start: int  # first bucket row
    stop: int  # one past the last bucket row

    @property
    def n_buckets(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class OverlapGroup:
    """One pipeline stage: the buckets whose collective is issued together."""

    slices: tuple[GroupSlice, ...]
    rank: int  # max availability rank of any bucket in the group
    wire_bytes: int  # payload bytes this group ships to ONE peer

    @property
    def n_buckets(self) -> int:
        return sum(s.n_buckets for s in self.slices)


@dataclasses.dataclass(frozen=True)
class OverlapSchedule:
    """Issue-ordered bucket groups for the pipelined exchange."""

    layout: BucketLayout
    groups: tuple[OverlapGroup, ...]  # reverse-AD availability order

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_buckets(self) -> int:
        return sum(g.n_buckets for g in self.groups)


def _path_rank(path) -> int | None:
    names = [getattr(k, "key", getattr(k, "name", getattr(k, "idx", None))) for k in path]
    names = [str(n) for n in names if n is not None]
    for needle, rank in _STAGE_RANKS:
        if any(needle == n for n in names):
            return rank
    return None


def reverse_ad_ranks(tree) -> tuple[int, ...]:
    """Per-leaf availability rank, tree-flatten order (lower = earlier grad).

    Transformer-shaped trees rank by stage (head/final_norm < blocks <
    encoder < embed); anything else falls back to reversed flatten order.
    """
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    ranks = [_path_rank(path) for path, _ in paths_leaves]
    if any(r is None for r in ranks):
        n = len(ranks)
        return tuple(n - 1 - i for i in range(n))
    return tuple(ranks)


def _bucket_ranks(layout: BucketLayout, leaf_ranks: tuple[int, ...]) -> list[list[int]]:
    """Per (dtype-group, bucket) availability rank = max rank of its leaves."""
    bs = layout.bucket_size
    per_group = [[-1] * g.n_buckets for g in layout.groups]
    for slot, rank in zip(layout.slots, leaf_ranks):
        if slot.size == 0:
            continue
        first = slot.offset // bs
        last = (slot.offset + slot.size - 1) // bs
        row = per_group[slot.group]
        for b in range(first, last + 1):
            row[b] = max(row[b], rank)
    for gi, row in enumerate(per_group):
        for b, r in enumerate(row):
            if r < 0:  # padding-only trailing bucket: ride with the last real one
                row[b] = row[b - 1] if b else 0
    return per_group


def build_schedule(
    layout: BucketLayout,
    params,
    *,
    n_groups: int = 4,
    comp: Compressor | None = None,
) -> OverlapSchedule:
    """Derive the static pipeline schedule for ``layout`` over ``params``.

    ``params`` may be arrays or ``jax.eval_shape`` structs — only the tree
    structure is read. ``comp`` sets the per-bucket wire cost used for the
    greedy balance (every bucket of one layout costs the same for a fixed
    compressor, so balance-by-bytes degenerates to balance-by-count — the
    bytes form is kept because mixed-precision transports won't have that
    symmetry).
    """
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    comp = comp or ScaledSignCompressor()
    leaf_ranks = reverse_ad_ranks(params)
    if len(leaf_ranks) != len(layout.slots):
        raise ValueError(
            f"params tree has {len(leaf_ranks)} leaves, layout expects {len(layout.slots)}"
        )
    ranks = _bucket_ranks(layout, leaf_ranks)
    ordered = sorted(
        (ranks[gi][bi], gi, bi)
        for gi, g in enumerate(layout.groups)
        for bi in range(g.n_buckets)
    )
    bucket_bytes = comp.wire_bits(layout.bucket_size) / 8.0
    n_groups = min(n_groups, len(ordered))
    total = bucket_bytes * len(ordered)

    groups: list[OverlapGroup] = []
    cut, acc = [], 0.0
    for rank, gi, bi in ordered:
        cut.append((rank, gi, bi))
        acc += bucket_bytes
        # close the segment once it crosses its proportional share of the
        # total bytes (greedy balance); the last group takes the remainder
        if len(groups) < n_groups - 1 and acc >= (len(groups) + 1) * total / n_groups:
            groups.append(_close_group(cut, bucket_bytes))
            cut = []
    if cut or not groups:
        groups.append(_close_group(cut, bucket_bytes))
    return OverlapSchedule(layout=layout, groups=tuple(groups))


def _close_group(cut: list[tuple[int, int, int]], bucket_bytes: float) -> OverlapGroup:
    slices: list[GroupSlice] = []
    for rank, gi, bi in cut:
        last = slices[-1] if slices else None
        if last is not None and last.group == gi and last.stop == bi:
            slices[-1] = GroupSlice(gi, last.start, bi + 1)
        else:
            slices.append(GroupSlice(gi, bi, bi + 1))
    return OverlapGroup(
        slices=tuple(slices),
        rank=max((r for r, _, _ in cut), default=0),
        wire_bytes=int(bucket_bytes * len(cut)),
    )
