"""Async overlap scheduler: pipeline bucket compression + collectives with
backward compute.

The bucketed comm layer (PR 2) made every bucket an independent stream; this
package cashes that in. One aggregator call after the full backward pays the
entire wire latency serially — instead we derive a static
:class:`~repro.overlap.schedule.OverlapSchedule` from the
:class:`~repro.comm.bucketize.BucketLayout` plus the model's reverse-AD
structure, and execute the exchange as a pipeline of bucket *groups*: the
collective for group *k* (whose gradients become available first in the
backward pass) is issued while group *k+1* is still being compressed — and,
with the staged grad-fn in :mod:`repro.train.steps`, while the earlier
layers' backward is still running.

``schedule``
    Static grouping of buckets by reverse-AD availability rank, greedy-
    balanced by wire bytes; pure function of (layout, param structure).
``ring``
    Compatibility re-export of :mod:`repro.comm.backends.ring` — the
    double-buffered ``ppermute`` ring exchange was promoted to a collective
    *backend* so any payload-mean strategy can ride it
    (``strategy="ef_ring"``, or ``CommSpec(backend="ring")``).
``pipeline``
    The executor :func:`repro.comm.make_aggregator` builds when
    ``spec.overlap`` is set, plus the pipeline latency model that turns
    measured per-group component times into the exposed-communication
    metric the bench suite gates.
"""

from repro.overlap.pipeline import (
    exposure_report,
    make_overlapped_aggregator,
    proportional_exposure,
)
from repro.overlap.ring import ring_decode_mean
from repro.overlap.schedule import (
    GroupSlice,
    OverlapGroup,
    OverlapSchedule,
    build_schedule,
    reverse_ad_ranks,
)

__all__ = [
    "GroupSlice",
    "OverlapGroup",
    "OverlapSchedule",
    "build_schedule",
    "exposure_report",
    "make_overlapped_aggregator",
    "proportional_exposure",
    "reverse_ad_ranks",
    "ring_decode_mean",
]
