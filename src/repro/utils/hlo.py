"""HLO-text analysis: trip-count-aware FLOP / byte / collective accounting.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a while-loop
body ONCE, so a scan-over-layers program (ours: L-repeat stacks, chunked
attention, chunked mamba) under-reports FLOPs and collective traffic by the
trip count. This module parses the post-SPMD per-device HLO, reconstructs the
computation call graph (while bodies/conditions, fusions, calls), extracts
each while loop's trip count from its condition computation (jax scans lower
to ``iv < constant``), and multiplies every op's cost by the product of trip
counts on its call chain.

Estimators (per device, per step):
  * ``dot_flops``        — 2 · Πout · Πcontract per dot, × multiplier
  * ``collective_bytes`` — result bytes of all-reduce/all-gather/
                           reduce-scatter/all-to-all/collective-permute,
                           × multiplier (async -start/-done counted once)
  * ``hbm_bytes``        — Σ (operand + result bytes) over materializing ops
                           (fusion/dot/copy/collectives/scatter/...),
                           × multiplier — an "every top-level op round-trips
                           HBM" model.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_CONST_RE = re.compile(r"^[su](?:8|16|32|64)\[\]\s*$")


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        total += _shape_elems(dims) * _DTYPE_BYTES[dtype]
    return total


class _Comp:
    __slots__ = ("name", "ops", "symbols", "whiles", "calls", "int_consts")

    def __init__(self, name: str):
        self.name = name
        # ops: list of (opname, result_type, operands_rest, full_rest)
        self.ops: list[tuple[str, str, str]] = []
        self.symbols: dict[str, str] = {}  # %name -> result type str
        self.whiles: list[tuple[str, str]] = []  # (body, cond)
        self.calls: list[str] = []
        self.int_consts: list[int] = []


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and "->" in line:
            hdr = _COMP_HDR.match(line.strip())
            if hdr:
                cur = _Comp(hdr.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            # parameters: `%x = TYPE parameter(0)` are covered by _OP_LINE;
            # anything else (metadata continuation) is skipped
            continue
        name, rtype, opname, rest = m.groups()
        cur.symbols[name] = rtype
        cur.ops.append((opname, rtype, rest))
        if opname == "constant" and _CONST_RE.match(rtype):
            cm = re.match(r"(\d+)\)", rest)
            if cm:
                cur.int_consts.append(int(cm.group(1)))
        if opname == "while":
            bm = re.search(r"body=%?([\w.\-]+)", rest)
            cm2 = re.search(r"condition=%?([\w.\-]+)", rest)
            if bm and cm2:
                cur.whiles.append((bm.group(1), cm2.group(1)))
        for key in ("calls=", "to_apply="):
            km = re.search(re.escape(key) + r"%?([\w.\-]+)", rest)
            if km:
                cur.calls.append(km.group(1))
        bm2 = re.search(r"branch_computations=\{([^}]*)\}", rest)
        if bm2:
            for n in re.split(r",\s*", bm2.group(1)):
                n = n.strip().lstrip("%")
                if n:
                    cur.calls.append(n)
    return comps


def _trip_count(cond: _Comp) -> int:
    """jax loops: condition computes `iv < bound` with `bound` a scalar int
    constant living in the condition computation (possibly passed into a
    wrapped-compare fusion). Heuristic: the largest scalar int constant."""
    if cond.int_consts:
        return max(1, max(cond.int_consts))
    return 1


def _multipliers(comps: dict[str, _Comp]) -> dict[str, float]:
    referenced: set[str] = set()
    for c in comps.values():
        for b, cn in c.whiles:
            referenced.update((b, cn))
        referenced.update(c.calls)
    roots = [n for n in comps if n not in referenced]
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth: int):
        if name not in comps or depth > 64:
            return
        if m <= mult[name]:
            return
        mult[name] = m
        c = comps[name]
        for body, cond in c.whiles:
            t = _trip_count(comps[cond]) if cond in comps else 1
            visit(body, m * t, depth + 1)
            visit(cond, m * t, depth + 1)
        for callee in c.calls:
            visit(callee, m, depth + 1)

    for r in roots:
        visit(r, 1.0, 0)
    return dict(mult)


def _operand_names(rest: str) -> list[str]:
    """Operand symbol names from an op call's argument list.

    Handles both HLO operand styles: bare (``dot(%a, %b)`` / ``dot(a, b)``)
    and typed (``dot(f32[2,3]{1,0} %a, ...)``) — the name is the last
    whitespace token of each comma-separated operand.
    """
    call = rest.split(")", 1)[0]
    names = re.findall(r"%([\w.\-]+)", call)
    if names:
        return names
    # bare style: split on commas (none appear inside shapes here), last token
    return [tok.strip().split()[-1] for tok in call.split(",") if tok.strip()]


def _dot_flops(rtype: str, rest: str, symbols: dict[str, str]) -> float:
    out_shapes = _SHAPE_RE.findall(rtype)
    if not out_shapes:
        return 0.0
    out_elems = _shape_elems(out_shapes[0][1])
    operands = _operand_names(rest)
    if not operands:
        return 0.0
    lhs_type = symbols.get(operands[0], "")
    lhs_shape = _SHAPE_RE.search(lhs_type)
    if not lhs_shape:
        return 0.0
    lhs_dims = [int(d) for d in lhs_shape.group(2).split(",")] if lhs_shape.group(2) else []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


_HBM_OPS = frozenset(
    (
        "fusion", "dot", "copy", "scatter", "gather", "convolution",
        "dynamic-slice", "dynamic-update-slice", "reduce", "transpose",
        "convert", "broadcast", "pad", "concatenate", "slice",
        "select-and-scatter", "reduce-window", "sort",
    )
    + COLLECTIVES
    + tuple(c + "-start" for c in COLLECTIVES)
)


def _operand_bytes(rest: str, symbols: dict[str, str]) -> int:
    total = 0
    for nm in _operand_names(rest):
        total += shape_bytes(symbols.get(nm, ""))
    return total


def analyze(text: str) -> dict:
    comps = _parse(text)
    mult = _multipliers(comps)

    flops = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, float] = defaultdict(float)
    hbm = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        is_fused = name.startswith(("fused_", "wrapped_")) or "fused_computation" in name
        for opname, rtype, rest in comp.ops:
            if opname == "dot":
                flops += m * _dot_flops(rtype, rest, comp.symbols)
            base = opname[:-6] if opname.endswith("-start") else opname
            if base in COLLECTIVES and not opname.endswith("-done"):
                coll_bytes[base] += m * shape_bytes(rtype)
                coll_count[base] += m
            if not is_fused and opname in _HBM_OPS:
                hbm += m * (shape_bytes(rtype) + _operand_bytes(rest, comp.symbols))

    return {
        "dot_flops": flops,
        "collective_bytes": {
            "total_bytes": sum(coll_bytes.values()),
            "by_kind_bytes": dict(coll_bytes),
            "by_kind_count": dict(coll_count),
        },
        "hbm_bytes": hbm,
    }


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-aware collective accounting (see :func:`analyze`)."""
    return analyze(hlo_text)["collective_bytes"]


def op_histogram(hlo_text: str, ops=("fusion", "dot", "scatter", "gather", "custom-call")) -> dict:
    hist = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+)", line)
        if m and m.group(1) in ops:
            hist[m.group(1)] += 1
    return dict(hist)
