"""jax version-compat shims (target range: 0.4.37 → current).

The repo is written against the modern jax API surface; everything that
drifted between 0.4.x and 0.6+ funnels through here so call sites stay
clean. Companion shims live in repro.launch.mesh (``use_mesh``, AxisType).
"""

from __future__ import annotations

from typing import Callable

import jax


def jax_version_tuple() -> tuple[int, int]:
    return tuple(int(x) for x in jax.__version__.split(".")[:2])


# jaxlib 0.4.x: known-broken partial-manual shard_map collectives etc.;
# version-keyed test xfails hang off this single flag
OLD_JAX = jax_version_tuple() < (0, 5)


def shard_map(f: Callable, *, mesh, in_specs, out_specs, manual_axes=None):
    """``jax.shard_map`` (≥ 0.6, ``axis_names``/``check_vma``) or
    ``jax.experimental.shard_map`` (0.4.x, ``auto``/``check_rep``).

    ``manual_axes``: mesh axes the body references collectively; the rest stay
    GSPMD-auto. ``None`` means fully manual. Replication checking is disabled
    in both dialects — the strategies' RNG-key plumbing defeats the inferencer.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if manual_axes is not None:
            kw["axis_names"] = frozenset(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {"check_rep": False}
    if manual_axes is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _shard_map(f, mesh, in_specs, out_specs, **kw)
