"""Small pytree arithmetic helpers (we do not ship optax/flax offline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_dot(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    )
    return sum(leaves, start=jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_size(a) -> int:
    """Total number of scalar elements in the pytree (static)."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
