"""Pallas TPU remote-DMA ring exchange of sign-compressed bucket payloads.

The ``pallas_dma`` collective backend (``repro.comm.backends.pallas_dma``).
Same hop structure as the ppermute ring (``repro.comm.backends.ring``): W−1
double-buffered hops circulate each worker's ORIGINAL compressed payload —
``(nb, bs/32)`` uint32 sign words + ``(nb,)`` fp32 scales — around the ring,
but the hop itself is a ``pltpu.make_async_remote_copy`` issued from inside
one Pallas kernel instead of a ``lax.ppermute`` the XLA scheduler has to
place. Two kernels, one contract:

1. :func:`dma_ring_gather_slots` — the remote-DMA kernel. Per hop it RDMAs
   the in-flight compressed payload to the right neighbor (double-buffered
   send/recv comm slots, per-slot DMA semaphores, neighbor barrier before the
   first hop) and stores each arrival into its canonical origin-id slot — the
   exact ``(W, nb, bs/32)`` layout ``lax.all_gather`` would produce, except it
   is 32× smaller than a gradient stack because it never leaves the wire
   format.
2. the fused decompress-mean (``kernels.ops.bucket_decompress_mean``, the
   existing gridded Pallas kernel) — accumulates ±scale signs straight out of
   the compressed slot words in VMEM, one bucket block at a time.

So the wire never materializes a dense per-worker gradient in HBM: HBM holds
only compressed slots (d/8 bytes per worker) and the single (nb, bs) fp32
mean. Decoding in canonical origin order makes the result bitwise-equal to
``ef_allgather`` / ``ef_ring`` on every worker — the replication-safety
argument of the ppermute ring (see its module docstring) carries over
verbatim, and the subprocess trajectory tests pin it.

CPU testability: ``make_async_remote_copy`` needs real TPU interconnect, so
the multi-device kernel is compile-gated (``@pytest.mark.tpu``). Everything
around it is oracle-checked everywhere: the hop/arrival schedule and the
slot-store body have pure-jnp oracles in :mod:`repro.kernels.ref`
(``dma_ring_slots_ref`` / ``dma_ring_mean_ref``), and the single-worker
degenerate of the kernel (slot seeding, no DMA) runs in interpret mode on any
backend — that is what the ``-m pallas`` tier exercises in CI.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU-only primitives (remote DMA, semaphores); absent on some builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exercised only on pallas-less builds
    pltpu = None

AxisNames = tuple[str, ...]

# one collective_id per concurrently-live ring kernel (we only ever run one)
RING_COLLECTIVE_ID = 7


def supported() -> bool:
    """True when the remote-DMA kernel can actually run (TPU + pltpu)."""
    return pltpu is not None and jax.default_backend() == "tpu"


def _compiler_params(collective_id: int):
    """Version-portable Mosaic params: the kernel has side effects (RDMA into
    a peer) and participates in a collective."""
    if hasattr(pltpu, "CompilerParams"):
        return pltpu.CompilerParams(has_side_effects=True, collective_id=collective_id)
    if hasattr(pltpu, "TPUCompilerParams"):  # jax 0.4.3x name
        return pltpu.TPUCompilerParams(has_side_effects=True, collective_id=collective_id)
    return dict(mosaic=dict(has_side_effects=True, collective_id=collective_id))


def _seed_slots_kernel(widx_ref, words_ref, scales_ref, slot_words_ref, slot_scales_ref):
    """world == 1 degenerate: canonical slots = just our own payload. No DMA,
    so this body is interpret-mode safe — the ``-m pallas`` oracle tier runs
    it on CPU to pin the slot-store layout against ``dma_ring_slots_ref``."""
    del widx_ref  # the only worker is origin 0
    slot_words_ref[...] = words_ref[...][None]
    slot_scales_ref[...] = scales_ref[...]


def _ring_slots_kernel(
    widx_ref,
    words_ref,
    scales_ref,
    slot_words_ref,
    slot_scales_ref,
    comm_words,
    comm_scales,
    send_sems,
    recv_sems,
    *,
    world: int,
):
    """W−1 double-buffered remote-DMA hops → canonical origin-id slots.

    ``widx_ref`` (SMEM) is this device's linear index on the ring axis;
    ``comm_*`` are the 2-deep VMEM communication slots the RDMA alternates
    between (send from ``step % 2``, receive into ``(step+1) % 2`` — the
    arrival of hop *t* is the send buffer of hop *t+1*, so nothing is copied
    between hops). Payloads stay sign-compressed on the wire for every hop.
    """
    my_id = widx_ref[0]
    right = lax.rem(my_id + 1, world)
    left = lax.rem(my_id + world - 1, world)

    # neighbor barrier: no RDMA may land in a peer that has not yet entered
    # the kernel (its comm slots would be uninitialized VMEM)
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=left)
    pltpu.semaphore_signal(barrier, inc=1, device_id=right)
    pltpu.semaphore_wait(barrier, 2)

    # canonical slot my_id ← own payload; comm slot 0 seeds hop 0's send
    slot_words_ref[pl.ds(my_id, 1)] = words_ref[...][None]
    slot_scales_ref[pl.ds(my_id, 1)] = scales_ref[...]
    comm_words[0] = words_ref[...]
    comm_scales[0] = scales_ref[...]

    for step in range(world - 1):  # static W: unrolled, slots alternate
        send_slot = step % 2
        recv_slot = (step + 1) % 2
        w_rdma = pltpu.make_async_remote_copy(
            src_ref=comm_words.at[send_slot],
            dst_ref=comm_words.at[recv_slot],
            send_sem=send_sems.at[0, send_slot],
            recv_sem=recv_sems.at[0, recv_slot],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        s_rdma = pltpu.make_async_remote_copy(
            src_ref=comm_scales.at[send_slot],
            dst_ref=comm_scales.at[recv_slot],
            send_sem=send_sems.at[1, send_slot],
            recv_sem=recv_sems.at[1, recv_slot],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        w_rdma.start()
        s_rdma.start()
        w_rdma.wait()
        s_rdma.wait()
        # hop t's arrival originated at (my_id − t − 1) mod W; storing it by
        # origin id reproduces the all-gather layout on every worker
        origin = lax.rem(my_id + world - step - 1, world)
        slot_words_ref[pl.ds(origin, 1)] = comm_words[recv_slot][None]
        slot_scales_ref[pl.ds(origin, 1)] = comm_scales[recv_slot]


def dma_ring_gather_slots(
    widx: jax.Array,
    words: jax.Array,
    scales: jax.Array,
    *,
    world: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Gather all W compressed payloads into canonical origin-id slots.

    ``widx`` () int32 ring index, ``words`` (nb, bs/32) u32, ``scales`` (nb,)
    f32 → ``((W, nb, bs/32) u32, (W, nb) f32)``. Runs inside the fully-manual
    ``shard_map`` of the bucketed aggregator. ``world == 1`` needs no DMA and
    is interpret-safe; the multi-device kernel requires a real TPU ring.
    """
    if pltpu is None:
        raise NotImplementedError("pallas TPU primitives unavailable in this jax build")
    nb, m = words.shape
    widx = jnp.asarray(widx, jnp.int32).reshape(1)
    scales_row = scales.astype(jnp.float32).reshape(1, nb)
    out_shape = [
        jax.ShapeDtypeStruct((world, nb, m), jnp.uint32),
        jax.ShapeDtypeStruct((world, nb), jnp.float32),
    ]
    smem = getattr(pltpu, "SMEM", None) or pltpu.TPUMemorySpace.SMEM
    in_specs = [
        pl.BlockSpec(memory_space=smem),
        pl.BlockSpec((nb, m), lambda: (0, 0)),
        pl.BlockSpec((1, nb), lambda: (0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((world, nb, m), lambda: (0, 0, 0)),
        pl.BlockSpec((world, nb), lambda: (0, 0)),
    ]
    if world == 1:
        slot_w, slot_s = pl.pallas_call(
            _seed_slots_kernel,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(widx, words, scales_row)
        return slot_w, slot_s
    slot_w, slot_s = pl.pallas_call(
        functools.partial(_ring_slots_kernel, world=world),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((2, nb, m), jnp.uint32),  # comm_words send/recv slots
            pltpu.VMEM((2, 1, nb), jnp.float32),  # comm_scales send/recv slots
            pltpu.SemaphoreType.DMA((2, 2)),  # send sems (words/scales × slot)
            pltpu.SemaphoreType.DMA((2, 2)),  # recv sems
        ],
        compiler_params=_compiler_params(RING_COLLECTIVE_ID),
        interpret=interpret,
    )(widx, words, scales_row)
    return slot_w, slot_s


def dma_ring_slot_stack(
    words: jax.Array,
    scales: jax.Array,
    ef_axes: AxisNames,
    world: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Remote-DMA ring exchange → canonical origin-id slot stacks.

    The slot-native backend entry point (``PayloadStack.slots()`` on the
    ``pallas_dma`` backend): gather every worker's compressed payload into
    ``((W, nb, bs/32) u32, (W, nb) f32)`` — the exact all-gather layout,
    still in the wire format, so the robust order statistics decode from
    slots the dense gradient never touched. ``dma_ring_slots_ref`` is the
    hop-by-hop oracle; the stack is worker-invariant by construction.
    """
    axis = ef_axes[0]  # single-axis ring, validated at spec time
    widx = lax.axis_index(axis)
    return dma_ring_gather_slots(widx, words, scales, world=world, interpret=interpret)


def dma_ring_decode_mean(
    words: jax.Array,
    scales: jax.Array,
    ef_axes: AxisNames,
    world: int,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Remote-DMA ring exchange + fused decompress-mean → (nb, bs) fp32.

    The backend entry point: DMA-gather compressed slots in canonical order,
    then accumulate ±scale signs straight from the slot words with the
    gridded Pallas mean kernel — decode order identical to ``ef_allgather``,
    so the result is bitwise-equal on every worker.
    """
    from repro.kernels import ops

    axis = ef_axes[0]  # single-axis ring, validated at spec time
    widx = lax.axis_index(axis)
    slot_w, slot_s = dma_ring_gather_slots(widx, words, scales, world=world, interpret=interpret)
    force = "pallas" if interpret and jax.default_backend() != "tpu" else None
    return ops.bucket_decompress_mean(slot_w, slot_s, force=force)
