"""Pallas TPU flash-attention kernel (forward) — the serving-path hot spot.

The XLA chunked attention (repro.models.layers.chunked_attention) is the
framework's portable implementation; this kernel is the TPU-native version
of the same online-softmax algorithm with explicit VMEM tiling:

  grid = (batch·heads, Sq/BLOCK_Q)  — one core-resident q block per cell,
  inner fori over KV blocks with (acc, m, l) in VREGs/VMEM.

BlockSpecs stage q (BLOCK_Q, D), k/v (Sk, D) per (b,h); for long Sk the
kv operand streams HBM→VMEM block-by-block via the explicit fori slicing
(pl.dynamic_slice) so resident VMEM is O(BLOCK_Q·D + BLOCK_K·D).

Causal + sliding-window masking matches ``ref_attention`` exactly; validated
in interpret mode against the pure-jnp oracle over shape/window sweeps
(tests/test_flash_kernel.py). Forward-only: the training path keeps the XLA
implementation (jax.checkpoint recompute); serving (prefill) is where the
fused kernel pays.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

BLOCK_Q = 128
BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sk: int, scale: float,
                  causal: bool, window: int, block_k: int):
    # q_ref: (1, BLOCK_Q, D); k_ref/v_ref: (1, SK_PAD, D); o_ref like q_ref
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    bq, d = q.shape
    skp = k_ref.shape[1]
    nk = skp // block_k

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(kidx, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(kidx * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kidx * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (BLOCK_Q, BLOCK_K) on the MXU
        k_pos = kidx * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = k_pos < sk
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D) — kv already expanded to H query heads
    k: jax.Array,  # (B, Sk, H, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    # layout: fold (B, H) into the grid's first axis; pad S to block multiples
    nq = (sq + block_q - 1) // block_q
    skp = ((sk + block_k - 1) // block_k) * block_k
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, nq * block_q - sq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    qt = qt.reshape(b * h, nq * block_q, d)
    kt = kt.reshape(b * h, skp, d)
    vt = vt.reshape(b * h, skp, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, sk=sk, scale=scale, causal=causal, window=window,
            block_k=block_k,
        ),
        grid=(b * h, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, skp, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, skp, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * block_q, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)

    out = out.reshape(b, h, nq * block_q, d)[:, :, :sq]
    return out.transpose(0, 2, 1, 3)
