"""Jit'd public wrappers around the EF-sign kernels.

``ef_sign_step(g, e, gamma)`` runs the full fused pipeline on an arbitrary
flat tensor:

    scale  = ‖γg+e‖₁ / d        (pass 1: blocked partial-L1 + tiny host sum)
    words  = bitpack(sign(γg+e))
    e_new  = (γg+e) − scale·sign(γg+e)
    Δ      = scale·sign(γg+e)   (reconstructable from words+scale — not returned)

Implementation selection: the Pallas path runs on TPU (or anywhere with
``interpret=True``); the jnp reference path is the default on CPU so that the
512-device dry-run never traces a Pallas call. ``force`` overrides for tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ef_sign, ref

LANE = ref.LANE


def _backend() -> str:
    return jax.default_backend()


def _use_pallas(force: str | None) -> tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if force == "pallas":
        return True, _backend() != "tpu"
    if force == "ref":
        return False, False
    return (_backend() == "tpu"), False


def pad_to_rows(x: jax.Array) -> tuple[jax.Array, int]:
    """Flatten and zero-pad to a (rows, LANE) view; returns (view, orig_n)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    rows = max(1, (n + LANE - 1) // LANE)
    flat = jnp.pad(flat, (0, rows * LANE - n))
    return flat.reshape(rows, LANE), n


@functools.partial(jax.jit, static_argnames=("force",))
def ef_sign_step(g: jax.Array, e: jax.Array, gamma: jax.Array, *, force: str | None = None):
    """Fused EF-SIGNSGD compression of one tensor.

    Returns ``(words, scale, e_new)`` with shapes ``((rows,32) u32, () f32,
    g.shape f32)``. Note the padded tail influences the L1 sum by 0 (zeros) —
    the scale divides by the *true* n, matching Alg. 1 exactly.
    """
    use_pallas, interpret = _use_pallas(force)
    gv, n = pad_to_rows(g)
    ev, _ = pad_to_rows(e)
    gamma = jnp.asarray(gamma, jnp.float32)

    if use_pallas:
        partial = ef_sign.l1_partial(gv, ev, gamma, interpret=interpret)
    else:
        partial = ref.l1_partial_ref(gv, ev, gamma)
    scale = jnp.sum(partial) / float(n)

    if use_pallas:
        words, e_new = ef_sign.ef_sign_compress(gv, ev, gamma, scale, interpret=interpret)
    else:
        words, e_new = ref.ef_sign_compress_ref(gv, ev, gamma, scale)
    e_new = e_new.reshape(-1)[:n].reshape(g.shape)
    return words, scale, e_new


@functools.partial(jax.jit, static_argnames=("force",))
def decompress_mean(words: jax.Array, scales: jax.Array, *, force: str | None = None):
    """Mean of W sign payloads: (W,rows,32) u32 + (W,) f32 → (rows,LANE) f32."""
    use_pallas, interpret = _use_pallas(force)
    if use_pallas:
        return ef_sign.sign_decompress_mean(words, scales, interpret=interpret)
    return ref.sign_decompress_mean_ref(words, scales)


BUCKET_PALLAS_MULTIPLE = 4096  # bs/32 words must tile the 128-lane registers


def _bucket_use_pallas(force: str | None, bs: int) -> tuple[bool, bool]:
    use_pallas, interpret = _use_pallas(force)
    if bs % BUCKET_PALLAS_MULTIPLE != 0 and force != "pallas":
        return False, False
    return use_pallas, interpret


@functools.partial(jax.jit, static_argnames=("fixed_scale", "force"))
def ef_sign_bucket_step(
    g: jax.Array,
    e: jax.Array,
    *,
    fixed_scale: float | None = None,
    force: str | None = None,
):
    """Fused EF sign compression of a whole bucket stack (repro.comm path).

    ``g``/``e`` are (n_buckets, bucket_size) f32 (update and EF residual);
    returns ``(words (nb, bs/32) u32, scales (nb,) f32, e_new (nb, bs) f32,
    dens (nb,) f32)``. The stats pass emits per-bucket (L1, L2²) from ONE read
    of (g, e), so the scale AND the density metric φ = ‖p‖₁²/(bs·‖p‖₂²) come
    for free — no second pass over p as the old ``vmap(density)`` cost.
    Scaled sign uses the per-bucket L1 mean ‖p_b‖₁/bs (the padded tail of the
    last bucket is zero, deflating its scale slightly — EF absorbs the
    difference and the unflatten slice discards the tail); ``fixed_scale``
    selects the unscaled-sign wire format instead (scale is fixed but the
    stats pass still supplies the density).
    """
    nb, bs = g.shape
    if bs % 32 != 0:
        raise ValueError(f"bucket_size must be a multiple of 32, got {bs}")
    use_pallas, interpret = _bucket_use_pallas(force, bs)
    if use_pallas:
        l1, l2sq = ef_sign.bucket_stats(g, e, interpret=interpret)
    else:
        l1, l2sq = ref.bucket_stats_ref(g, e)
    dens = jnp.where(l2sq > 0, l1 * l1 / (float(bs) * l2sq), jnp.float32(1.0))
    if fixed_scale is not None:
        scales = jnp.full((nb,), fixed_scale, jnp.float32)
    else:
        scales = l1 / float(bs)
    if use_pallas:
        words, e_new = ef_sign.bucket_ef_sign_compress(g, e, scales, interpret=interpret)
    else:
        words, e_new = ref.bucket_ef_sign_compress_ref(g, e, scales)
    return words, scales, e_new, dens


@functools.partial(jax.jit, static_argnames=("force",))
def bucket_decompress_mean(words: jax.Array, scales: jax.Array, *, force: str | None = None):
    """Mean of W bucket payload stacks: (W, nb, bs/32) + (W, nb) → (nb, bs)."""
    use_pallas, interpret = _bucket_use_pallas(force, words.shape[-1] * 32)
    if use_pallas:
        return ef_sign.bucket_sign_decompress_mean(words, scales, interpret=interpret)
    return ref.bucket_decompress_mean_ref(words, scales)


@functools.partial(jax.jit, static_argnames=("force",))
def bucket_sign_accumulate(
    acc: jax.Array, words: jax.Array, scales: jax.Array, *, force: str | None = None
):
    """Fused decompress-accumulate (ring hop): acc + scaleᵦ·unpack(wordsᵦ).

    (nb, bs) f32 + (nb, bs/32) u32 + (nb,) f32 → (nb, bs) f32.
    """
    use_pallas, interpret = _bucket_use_pallas(force, words.shape[-1] * 32)
    if use_pallas:
        return ef_sign.bucket_sign_accumulate(acc, words, scales, interpret=interpret)
    return ref.bucket_sign_accumulate_ref(acc, words, scales)


def bucket_sign_decode(words: jax.Array, scales: jax.Array, bucket_size: int) -> jax.Array:
    """Single payload stack decode: (nb, bs/32) + (nb,) → (nb, bs)."""
    del bucket_size  # implied by the word count; kept for call-site clarity
    return ref.bucket_sign_decode_ref(words, scales)


def delta_from(words: jax.Array, scale: jax.Array, n: int, shape) -> jax.Array:
    """Reconstruct Δ = scale·sign(p) from a payload (for single-worker EF)."""
    out = ref.sign_decompress_ref(words, scale)
    return out.reshape(-1)[:n].reshape(shape)


def modeled_hbm_bytes_per_elem(fused: bool) -> float:
    """TPU-side HBM-traffic model for the EF-sign compression stage.

    Fused Pallas kernel (two passes sharing reads):
      L1 pass: read g + read e (8 B);  compress pass: read g + read e,
      write e' (12 B), write words (4/32 B) → 20.125 B/elem.
    Unfused XLA pipeline (each stage materializes):
      p = γg+e (r8, w4); scale = Σ|p| (r4); words = pack(sign p) (r4, w1/8);
      Δ = scale·unpack (r1/8, w4); e' = p−Δ (r8, w4) → 36.25 B/elem.

    The ratio (~1.8×) is the roofline bound on the compression stage; the
    kernels suite records both terms so the model is pinned by the baseline
    gate and any change to it is an explicit diff.
    """
    if fused:
        return 8.0 + 12.0 + 4.0 / 32.0
    return (8 + 4) + 4 + (4 + 4 / 32) + (4 / 32 + 4) + (8 + 4)
