"""Pallas TPU kernels (interpret-validated on CPU; TPU is the target):

* ef_sign      — fused EF-sign compression: γg+e → packed words + residual,
                 decompress-and-mean over gathered payloads, the whole-bucket
                 variants (single stats pass feeding scale AND density), and
                 the fused decompress-accumulate hop of the overlap ring
* flash_attention — forward flash attention (online softmax, VMEM-tiled)

``ops.py`` holds the jit'd public wrappers with backend dispatch; ``ref.py``
the pure-jnp oracles the tests assert against.
"""
