"""Pallas TPU decode-attention kernel: one query token vs a deep KV cache.

The decode shapes (decode_32k: B=128 × T=32k cache; long_500k: B=1 × 500k)
are pure HBM-bandwidth workloads — every step streams the whole cache once.
The XLA path materializes the (B,H,T) logits row and several softmax
intermediates; this kernel streams KV blocks through VMEM with an online
softmax so HBM traffic is exactly one cache read + one O(B·H·D) write.

grid = (B·H,); inner fori over T/BLOCK_T cache blocks. Supports the ring-
buffer validity mask (slot ≤ pos, or all-valid once wrapped) used by the
sliding-window caches.

Validated interpret=True against repro.models.layers.decode_attention.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLOCK_T = 512


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, *, t_valid_mode: str,
                   pos: int | None, block_t: int, scale: float, t_cache: int):
    # q_ref: (1, D); k_ref/v_ref: (1, T_PAD, D); o_ref: (1, D)
    q = q_ref[0].astype(jnp.float32) * scale  # (D,)
    d = q.shape[0]
    tp = k_ref.shape[1]
    nt = tp // block_t

    def body(ti, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(ti * block_t, block_t), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ti * block_t, block_t), :].astype(jnp.float32)
        s = k @ q  # (BLOCK_T,)
        slots = ti * block_t + jax.lax.iota(jnp.int32, block_t)
        mask = slots < t_cache
        if t_valid_mode == "prefix":
            mask = mask & (slots <= pos)
        # 'all': ring buffer past wrap-around — every real slot valid
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p)
        acc = corr * acc + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((d,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nt, body, (acc0, NEG_INF, 0.0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,  # (B, 1, H, D) — kv heads already expanded to H
    k_cache: jax.Array,  # (B, T, H, D)
    v_cache: jax.Array,
    pos: int,  # static position for masking (prefix mode)
    *,
    ring_full: bool = False,  # True → every slot valid (wrapped ring buffer)
    block_t: int = BLOCK_T,
    interpret: bool = False,
) -> jax.Array:
    b, _, h, d = q.shape
    t = k_cache.shape[1]
    tp = ((t + block_t - 1) // block_t) * block_t
    scale = 1.0 / math.sqrt(d)

    qt = q.reshape(b, h, d).reshape(b * h, d)
    kt = jnp.pad(k_cache.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    vt = jnp.pad(v_cache.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    kt = kt.reshape(b * h, tp, d)
    vt = vt.reshape(b * h, tp, d)

    out = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            t_valid_mode="all" if ring_full else "prefix",
            pos=pos, block_t=block_t, scale=scale, t_cache=t,
        ),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda bh: (bh, 0)),
            pl.BlockSpec((1, tp, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, tp, d), lambda bh: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)

    return out.reshape(b, 1, h, d)
