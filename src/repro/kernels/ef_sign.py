"""Pallas TPU kernels for fused error-feedback sign compression.

The compression path (Alg. 1 lines 4-7) is purely memory-bound: every byte of
gradient is read, signed, packed, and a residual written back. Composed from
stock XLA ops this costs ≥4 HBM round-trips of the tensor (p = γg+e; |p| sum;
sign+pack; e' = p−Δ). The kernels below fuse each stage into a single
HBM→VMEM→HBM pass:

  * ``l1_partial``          — per-row |γg+e| partial sums (reduction pass 1)
  * ``ef_sign_compress``    — γg+e → packed sign words + new residual, fused
  * ``sign_decompress_mean``— unpack W gathered payloads and average them

Layout: flat tensors are viewed as (rows, 1024) f32; rows are tiled into
VMEM blocks of BLOCK_ROWS×1024 (512 KiB per operand — three operands resident
≈ 1.5 MiB, comfortably inside the ~16 MiB VMEM budget with double buffering).
1024 lanes = 8×128 VPU tiles; the pack's reduction axis (32) stays in-register.

Validated in ``interpret=True`` mode on CPU against ``ref.py`` (tests sweep
rows/dtypes); TPU (v5e) is the compile target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
WORDS_PER_ROW = LANE // 32
BLOCK_ROWS = 128  # 128×1024 f32 = 512 KiB per operand block


def _grid(rows: int, block_rows: int) -> int:
    assert rows % block_rows == 0, (rows, block_rows)
    return rows // block_rows


# ---------------------------------------------------------------------------
# pass 1: per-row L1 of p = γg + e
# ---------------------------------------------------------------------------


def _l1_partial_kernel(gamma_ref, g_ref, e_ref, out_ref):
    gamma = gamma_ref[0]
    p = gamma * g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(jnp.abs(p), axis=-1)


def l1_partial(g, e, gamma, *, block_rows: int = BLOCK_ROWS, interpret: bool = False):
    rows = g.shape[0]
    block_rows = min(block_rows, rows)
    grid = (_grid(rows, block_rows),)
    return pl.pallas_call(
        _l1_partial_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # γ broadcast to every block
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
    )(gamma.reshape(1), g, e)


# ---------------------------------------------------------------------------
# pass 2: fused sign + bitpack + residual update
# ---------------------------------------------------------------------------


def _ef_sign_kernel(gamma_ref, scale_ref, g_ref, e_ref, words_ref, e_new_ref):
    gamma = gamma_ref[0]
    scale = scale_ref[0]
    p = gamma * g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    bits = (p >= 0).astype(jnp.uint32)  # (block_rows, LANE)
    br = bits.shape[0]
    b = bits.reshape(br, WORDS_PER_ROW, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words_ref[...] = jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)
    delta = scale * (2.0 * bits.astype(jnp.float32) - 1.0)
    e_new_ref[...] = p - delta


def ef_sign_compress(
    g, e, gamma, scale, *, block_rows: int = BLOCK_ROWS, interpret: bool = False
):
    """(rows,1024) γg+e → ((rows,32) uint32 packed signs, (rows,1024) residual)."""
    rows = g.shape[0]
    block_rows = min(block_rows, rows)
    grid = (_grid(rows, block_rows),)
    return pl.pallas_call(
        _ef_sign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, WORDS_PER_ROW), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, WORDS_PER_ROW), jnp.uint32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(gamma.reshape(1), scale.reshape(1), g, e)


# ---------------------------------------------------------------------------
# whole-bucket variants (repro.comm): one grid step per BUCKET, per-bucket
# scale. A bucket is a (bucket_size,) slice of the flattened grad stream;
# bucket_size % LANE == 0 keeps the pack's reduction axis in-register and the
# word row a whole number of 128-lane tiles. γ is folded into the update by
# the optimizer chain before bucketing, so p = g + e here.
# ---------------------------------------------------------------------------


def _bucket_l1_kernel(g_ref, e_ref, out_ref):
    p = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(jnp.abs(p), axis=-1)


def bucket_l1(g, e, *, interpret: bool = False):
    """Per-bucket L1 of p = g + e: (nb, bs) → (nb,)."""
    nb, bs = g.shape
    return pl.pallas_call(
        _bucket_l1_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=interpret,
    )(g, e)


def _bucket_stats_kernel(g_ref, e_ref, l1_ref, l2_ref):
    p = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    l1_ref[...] = jnp.sum(jnp.abs(p), axis=-1)
    l2_ref[...] = jnp.sum(p * p, axis=-1)


def bucket_stats(g, e, *, interpret: bool = False):
    """Per-bucket (L1, L2²) of p = g + e in one fused pass: (nb, bs) → 2×(nb,).

    Supersedes :func:`bucket_l1` on the comm path: the same HBM read of
    (g, e) also feeds the density metric, so the metric no longer costs a
    second pass over the bucket stack.
    """
    nb, bs = g.shape
    return pl.pallas_call(
        _bucket_stats_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(g, e)


def _bucket_ef_sign_kernel(scale_ref, g_ref, e_ref, words_ref, e_new_ref):
    scale = scale_ref[0]
    p = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    bits = (p >= 0).astype(jnp.uint32)  # (1, bs)
    bs = bits.shape[-1]
    b = bits.reshape(1, bs // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words_ref[...] = jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)
    delta = scale * (2.0 * bits.astype(jnp.float32) - 1.0)
    e_new_ref[...] = p - delta


def bucket_ef_sign_compress(g, e, scales, *, interpret: bool = False):
    """(nb, bs) p = g+e → ((nb, bs/32) u32 packed signs, (nb, bs) residual)."""
    nb, bs = g.shape
    return pl.pallas_call(
        _bucket_ef_sign_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),  # per-bucket scale
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs // 32), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs // 32), jnp.uint32),
            jax.ShapeDtypeStruct((nb, bs), jnp.float32),
        ],
        interpret=interpret,
    )(scales, g, e)


def _bucket_accumulate_kernel(scales_ref, acc_ref, words_ref, out_ref):
    # acc block (1, bs); words block (1, bs/32); scale (1,) — one VMEM-resident
    # decode fused with the add, no ±scale tensor ever hits HBM
    shifts = jnp.arange(32, dtype=jnp.uint32)
    wd = words_ref[...]  # (1, bs/32)
    bits = (wd[..., None] >> shifts) & jnp.uint32(1)
    signs = 2.0 * bits.reshape(out_ref.shape).astype(jnp.float32) - 1.0
    out_ref[...] = acc_ref[...] + scales_ref[0] * signs


def bucket_sign_accumulate(acc, words, scales, *, interpret: bool = False):
    """Fused decompress-accumulate: acc + scaleᵦ·unpack(wordsᵦ) per bucket.

    acc (nb, bs) f32, words (nb, bs/32) u32, scales (nb,) f32 → (nb, bs) f32.
    The per-hop accumulation of the double-buffered ring aggregator: each
    arriving payload folds into the fp32 accumulator in a single
    HBM→VMEM→HBM pass (read acc + words, write acc'), so the ring's decode
    cost is spread across the W−1 hops instead of piling up after the last.
    """
    nb, bs = acc.shape
    return pl.pallas_call(
        _bucket_accumulate_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs // 32), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), jnp.float32),
        interpret=interpret,
    )(scales, acc, words)


def _bucket_decompress_mean_kernel(scales_ref, words_ref, out_ref, *, w: int):
    # words block: (w, 1, bs/32); scales: (w, 1); out: (1, bs)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(w):  # w is static; unrolled vector loop
        wd = words_ref[i]  # (1, bs/32)
        bits = (wd[..., None] >> shifts) & jnp.uint32(1)
        signs = 2.0 * bits.reshape(out_ref.shape).astype(jnp.float32) - 1.0
        acc = acc + scales_ref[i, 0] * signs
    out_ref[...] = acc / w


def bucket_sign_decompress_mean(words, scales, *, interpret: bool = False):
    """(W, nb, bs/32) u32 + (W, nb) scales → (nb, bs) mean of ±scaleᵢᵦ."""
    w, nb, m = words.shape
    return pl.pallas_call(
        functools.partial(_bucket_decompress_mean_kernel, w=w),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((w, 1), lambda i: (0, i)),
            pl.BlockSpec((w, 1, m), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m * 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, m * 32), jnp.float32),
        interpret=interpret,
    )(scales, words)


# ---------------------------------------------------------------------------
# decompress-and-mean over W gathered payloads
# ---------------------------------------------------------------------------


def _decompress_mean_kernel(scales_ref, words_ref, out_ref, *, w: int):
    # words block: (w, block_rows, WORDS_PER_ROW); scales: (w,)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(w):  # w is static (16/32); unrolled vector loop
        wd = words_ref[i]  # (block_rows, WORDS_PER_ROW)
        bits = (wd[..., None] >> shifts) & jnp.uint32(1)
        signs = 2.0 * bits.reshape(out_ref.shape).astype(jnp.float32) - 1.0
        acc = acc + scales_ref[i] * signs
    out_ref[...] = acc / w


def sign_decompress_mean(
    words, scales, *, block_rows: int = BLOCK_ROWS, interpret: bool = False
):
    """(W,rows,32) uint32 + (W,) scales → (rows,1024) mean of ±scaleᵢ."""
    w, rows, _ = words.shape
    block_rows = min(block_rows, rows)
    grid = (_grid(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_decompress_mean_kernel, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w,), lambda i: (0,)),
            pl.BlockSpec((w, block_rows, WORDS_PER_ROW), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(scales, words)
