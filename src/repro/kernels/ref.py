"""Pure-jnp oracles for the EF-sign kernels.

These define the exact semantics the Pallas kernels must match (tests sweep
shapes/dtypes and assert_allclose against these). Data layout: the flat
gradient is viewed as (rows, LANE) with LANE=1024 (ops.py pads); each row
packs into LANE/32 = 32 uint32 words.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 1024
WORDS_PER_ROW = LANE // 32

# sender counts up to this stay a Python unroll (bitwise-pinned against the
# Pallas kernel's unrolled accumulation); beyond it the mean decode rolls
# into a fori_loop so cohort-scale (10^4-sender) graphs stay O(1) ops
_UNROLL_MAX = 64


def l1_partial_ref(g: jax.Array, e: jax.Array, gamma: jax.Array) -> jax.Array:
    """Per-row L1 of the corrected step p = γ·g + e.  (rows, LANE) → (rows,)."""
    p = gamma * g.astype(jnp.float32) + e.astype(jnp.float32)
    return jnp.sum(jnp.abs(p), axis=-1)


def ef_sign_compress_ref(
    g: jax.Array, e: jax.Array, gamma: jax.Array, scale: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused EF sign compression (the paper's Alg. 1 lines 4-7 minus the norm).

    p      = γ·g + e
    words  = bitpack(p ≥ 0)                      (rows, 32) uint32
    e_new  = p − scale·sign(p)                   (rows, LANE) f32

    ``scale`` is the tensor-global ‖p‖₁/d computed from :func:`l1_partial_ref`.
    """
    p = gamma * g.astype(jnp.float32) + e.astype(jnp.float32)
    bits = (p >= 0).astype(jnp.uint32)  # (rows, LANE)
    rows = p.shape[0]
    b = bits.reshape(rows, WORDS_PER_ROW, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words = jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)
    delta = scale * (2.0 * bits.astype(jnp.float32) - 1.0)
    e_new = p - delta
    return words, e_new


def sign_decompress_ref(words: jax.Array, scale: jax.Array) -> jax.Array:
    """Unpack one payload: (rows, 32) uint32 → (rows, LANE) f32 of ±scale."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)  # (rows, 32, 32)
    rows = words.shape[0]
    signs = 2.0 * bits.reshape(rows, LANE).astype(jnp.float32) - 1.0
    return scale * signs


# ---------------------------------------------------------------------------
# whole-bucket variants (repro.comm): per-BUCKET scales instead of one global
# scale. Layout: (n_buckets, bucket_size) f32, bucket_size % 32 == 0, each
# bucket packing into bucket_size/32 uint32 words.
# ---------------------------------------------------------------------------


def bucket_l1_ref(g: jax.Array, e: jax.Array) -> jax.Array:
    """Per-bucket L1 of p = g + e.  (nb, bs) → (nb,)."""
    p = g.astype(jnp.float32) + e.astype(jnp.float32)
    return jnp.sum(jnp.abs(p), axis=-1)


def bucket_stats_ref(g: jax.Array, e: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-bucket (‖p‖₁, ‖p‖₂²) of p = g + e in ONE pass.  (nb, bs) → 2×(nb,).

    The L1 drives the scaled-sign scale and the pair drives the density
    φ = ‖p‖₁²/(d·‖p‖₂²) — emitting both from the same read of (g, e) is what
    removes the extra HBM pass the old ``vmap(density)(p)`` metric cost.
    """
    p = g.astype(jnp.float32) + e.astype(jnp.float32)
    return jnp.sum(jnp.abs(p), axis=-1), jnp.sum(p * p, axis=-1)


def bucket_sign_accumulate_ref(acc: jax.Array, words: jax.Array, scales: jax.Array) -> jax.Array:
    """Fused decompress-accumulate: acc + scaleᵦ·unpack(words).

    acc: (nb, bs) f32; words: (nb, bs/32) u32; scales: (nb,) f32. This is the
    per-hop accumulation of the ring aggregator — the payload is decoded
    straight into the accumulator (one read of acc + one read of words per
    element) instead of materializing the ±scale tensor and adding it.
    """
    return acc + bucket_sign_decode_ref(words, scales)


def bucket_ef_sign_compress_ref(
    g: jax.Array, e: jax.Array, scales: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused per-bucket EF sign compression.

    p      = g + e                              (nb, bs)
    words  = bitpack(p ≥ 0)                     (nb, bs/32) uint32
    e_new  = p − scales[b]·sign(p)              (nb, bs) f32
    """
    p = g.astype(jnp.float32) + e.astype(jnp.float32)
    nb, bs = p.shape
    bits = (p >= 0).astype(jnp.uint32)
    b = bits.reshape(nb, bs // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words = jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)
    delta = scales[:, None] * (2.0 * bits.astype(jnp.float32) - 1.0)
    return words, p - delta


def bucket_sign_decode_ref(words: jax.Array, scales: jax.Array) -> jax.Array:
    """(nb, bs/32) u32 + (nb,) scales → (nb, bs) f32 of ±scaleᵦ."""
    nb, m = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    signs = 2.0 * bits.reshape(nb, m * 32).astype(jnp.float32) - 1.0
    return scales[:, None] * signs


def bucket_decompress_mean_ref(words: jax.Array, scales: jax.Array) -> jax.Array:
    """Decompress-and-average W bucket payload stacks.

    words: (W, nb, bs/32) u32; scales: (W, nb) f32 → (nb, bs) f32. Sequential
    accumulation (same order as the Pallas kernel's unrolled loop). Past
    ``_UNROLL_MAX`` senders (federated cohorts, not worker rings) the Python
    unroll would put W copies of the decode in the graph and compile time
    goes superlinear, so the loop rolls into a ``fori_loop`` — the identical
    acc-then-add sequence, just not flattened at trace time.
    """
    w = words.shape[0]
    acc = jnp.zeros((words.shape[1], words.shape[2] * 32), jnp.float32)
    if w <= _UNROLL_MAX:
        for i in range(w):
            acc = acc + bucket_sign_decode_ref(words[i], scales[i])
        return acc / w

    def body(i, a):
        return a + bucket_sign_decode_ref(words[i], scales[i])

    return jax.lax.fori_loop(0, w, body, acc) / w


def dma_ring_slots_ref(
    words_all: jax.Array, scales_all: jax.Array, widx: int
) -> tuple[jax.Array, jax.Array]:
    """Hop-by-hop oracle of the remote-DMA ring's slot gather (dma_ring.py).

    ``words_all`` (W, nb, bs/32) / ``scales_all`` (W, nb) are every worker's
    original payload; the return is what worker ``widx``'s canonical slot
    buffers hold after W−1 hops. The whole ring is simulated: each hop
    forwards whatever sits in each worker's send slot to its right neighbor
    (payloads are never re-encoded), and worker ``widx`` files its arrival
    under the arrival's ORIGIN id — so the result must equal the plain
    all-gather stack for EVERY ``widx``, which is exactly the worker-
    invariance the kernel's bitwise-parity contract rests on.
    """
    world = words_all.shape[0]
    inflight = list(range(world))  # origin id in each worker's send slot
    slot_w = [None] * world
    slot_s = [None] * world
    slot_w[widx] = words_all[widx]
    slot_s[widx] = scales_all[widx]
    for _ in range(world - 1):
        # simultaneous hop: worker i's send slot lands at worker (i+1) % W
        inflight = [inflight[(i - 1) % world] for i in range(world)]
        origin = inflight[widx]
        slot_w[origin] = words_all[origin]
        slot_s[origin] = scales_all[origin]
    assert all(s is not None for s in slot_w), "ring must deliver every origin"
    return jnp.stack(slot_w), jnp.stack(slot_s)


def dma_ring_mean_ref(words_all: jax.Array, scales_all: jax.Array, widx: int) -> jax.Array:
    """End-to-end oracle of the ``pallas_dma`` backend for worker ``widx``:
    slot gather followed by the canonical-order decompress-mean. Equal to
    :func:`bucket_decompress_mean_ref` of the raw stack for every worker."""
    slot_w, slot_s = dma_ring_slots_ref(words_all, scales_all, widx)
    return bucket_decompress_mean_ref(slot_w, slot_s)


def sign_decompress_mean_ref(words: jax.Array, scales: jax.Array) -> jax.Array:
    """Decompress-and-average W payloads (the all-gather hot loop).

    words: (W, rows, 32) uint32;  scales: (W,) f32  →  (rows, LANE) f32.

    Accumulates worker payloads sequentially — the same summation order as the
    Pallas kernel's unrolled loop, so ref and kernel agree bit-for-bit.
    """
    w = words.shape[0]
    acc = jnp.zeros((words.shape[1], LANE), jnp.float32)
    for i in range(w):
        acc = acc + sign_decompress_ref(words[i], scales[i])
    return acc / w
