"""Deterministic synthetic datasets.

* token streams for LM training (structured enough that loss decreases),
* the exact §5.2/A.6 over-parameterized least-squares generator (Wilson et
  al.'17 construction) for the generalization experiments,
* the A.1 sparse-noise quadratic,
* a CIFAR-protocol proxy classification task (teacher-generated labels) for
  the Fig-4/Table-1 style algorithm comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------


def token_batch(key, batch: int, seq: int, vocab: int, *, order: int = 2) -> dict:
    """Markov-ish synthetic tokens: next token = affine function of previous
    ``order`` tokens mod vocab, plus noise — learnable structure."""
    k1, k2 = jax.random.split(key)
    x = jax.random.randint(k1, (batch, seq + 1), 0, vocab)
    # inject determinism: with prob .75 token t = (a·t-1 + b·t-2 + c) % vocab
    a, b, c = 31, 17, 7
    det = (a * x[:, :-2] + b * x[:, 1:-1] + c) % vocab
    coin = jax.random.bernoulli(k2, 0.75, det.shape)
    toks = x.at[:, 2:].set(jnp.where(coin, det, x[:, 2:]))
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def token_batches(seed: int, batch: int, seq: int, vocab: int) -> Iterator[dict]:
    step = 0
    while True:
        yield token_batch(jax.random.PRNGKey(seed * 100_003 + step), batch, seq, vocab)
        step += 1


# ---------------------------------------------------------------------------
# §5.2 / Appendix A.6: Wilson et al. over-parameterized least squares
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WilsonData:
    a_train: np.ndarray
    y_train: np.ndarray
    a_test: np.ndarray
    y_test: np.ndarray


def wilson_least_squares(seed: int = 0, n: int = 200, d_mult: int = 6) -> WilsonData:
    """Exact A.6 construction: n=200 points, d=6n=1200 dims.

    A[i,1]=y_i; A[i,2:4]=1; A[i, 4+5(i-1) .. 4+5(i-1)+2(1-y_i)] = 1 (1-indexed
    in the paper; 0-indexed here), rest 0. Random 50/50 train/test split.
    """
    rng = np.random.default_rng(seed)
    d = d_mult * n
    y = rng.choice([-1.0, 1.0], size=n)
    a = np.zeros((n, d), np.float64)
    for i in range(n):
        a[i, 0] = y[i]
        a[i, 1] = 1.0
        a[i, 2] = 1.0
        start = 3 + 5 * i
        width = 1 + int(2 * (1 - y[i]))  # y=+1 → 1 slot; y=−1 → 3 slots
        a[i, start : start + width] = 1.0
    perm = rng.permutation(n)
    tr, te = perm[: n // 2], perm[n // 2 :]
    return WilsonData(a[tr], y[tr], a[te], y[te])


# ---------------------------------------------------------------------------
# A.1 sparse-noise quadratic
# ---------------------------------------------------------------------------


def sparse_noise_grad(key, x: jax.Array, noise_std: float = 100.0) -> jax.Array:
    """∇f(x)=x for f = ½‖x‖²; gaussian noise N(0, 100²) on coordinate 0 only."""
    g = x
    noise = noise_std * jax.random.normal(key, ())
    return g.at[0].add(noise)


# ---------------------------------------------------------------------------
# CIFAR-protocol proxy classification task
# ---------------------------------------------------------------------------


def proxy_classification(
    seed: int = 0,
    n_train: int = 4096,
    n_test: int = 1024,
    dim: int = 256,
    classes: int = 10,
    teacher_width: int = 64,
    label_noise: float = 0.1,
):
    """Teacher-MLP-generated task with label noise: overfitting is possible
    (train acc → 100%) while test acc separates optimizers — the property the
    paper's Fig. 4 comparison relies on."""
    rng = np.random.default_rng(seed)
    w1 = rng.normal(size=(dim, teacher_width)) / np.sqrt(dim)
    w2 = rng.normal(size=(teacher_width, classes)) / np.sqrt(teacher_width)
    x = rng.normal(size=(n_train + n_test, dim)).astype(np.float32)
    logits = np.tanh(x @ w1) @ w2
    y = logits.argmax(-1)
    flip = rng.random(len(y)) < label_noise
    y[flip] = rng.integers(0, classes, flip.sum())
    return (
        (x[:n_train], y[:n_train].astype(np.int32)),
        (x[n_train:], y[n_train:].astype(np.int32)),
    )
